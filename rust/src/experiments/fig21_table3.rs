//! **Fig 21 + Table 3** — low-priority JCT stability under FIKIT
//! sharing (§4.5.4): service A runs high-priority tasks continuously,
//! service B inserts a low-priority task every second (100 total).
//!
//! The paper shows B's per-arrival JCT timeline is flat, with
//! coefficients of variation 0.095–0.164 across the ten combos — the
//! stability/predictability guarantee FIKIT gives background tenants.

use super::combos::{base_config, profile_combo_scratch, COMBOS, HIGH_KEY, LOW_KEY};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{run_with_profiles_scratch, SimScratch};
use crate::coordinator::Mode;
use crate::core::{Priority, Result, TaskKey};
use crate::metrics::TextTable;

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let inserts = opts.tasks(100);
    let interval_ms = 250u64;

    let mut table = TextTable::new(&["timeline", "σ (ms)", "μ (ms)", "CV = σ/μ", "sparkline"]);
    let mut series = Vec::new();
    let mut cvs = Vec::new();
    // One event-core scratch across all ten combos.
    let mut scratch = SimScratch::new();

    for combo in &COMBOS {
        let mut cfg: ExperimentConfig = base_config(opts);
        cfg.mode = Mode::Fikit;
        let horizon_ms = interval_ms * (inserts as u64 + 1);
        // A: continuous high-priority stream.
        cfg.services.push(
            ServiceConfig::new(combo.high, Priority::P0)
                .continuous_ms(horizon_ms)
                .with_key(HIGH_KEY),
        );
        // B: a low-priority task every second.
        cfg.services.push(
            ServiceConfig::new(combo.low, Priority::P3)
                .every_ms(interval_ms, inserts)
                .with_key(LOW_KEY),
        );
        let profiles = profile_combo_scratch(&cfg, &mut scratch)?;
        let report = run_with_profiles_scratch(&cfg, &profiles, &mut scratch)?;
        let svc = report
            .service(&TaskKey::new(LOW_KEY))
            .ok_or_else(|| crate::core::Error::Invariant("missing low service".into()))?;
        let stats = &svc.jct;
        cvs.push(stats.cv);
        series.push((format!("table3/{}/cv", combo.label), stats.cv));
        table.row(vec![
            combo.label.to_string(),
            format!("{:.3}", stats.std.as_millis_f64()),
            format!("{:.3}", stats.mean_ms()),
            format!("{:.4}", stats.cv),
            svc.timeline.sparkline().chars().take(40).collect(),
        ]);
    }

    let max_cv = cvs.iter().cloned().fold(0.0, f64::max);
    let stable = cvs.iter().filter(|cv| **cv < 0.5).count();
    let checks = vec![
        ShapeCheck::new(
            "all timelines stable (CV << 1)",
            max_cv < 0.6,
            format!("max CV {max_cv:.3} (paper band 0.095–0.164)"),
        ),
        ShapeCheck::new(
            "stability across combos",
            stable >= 9,
            format!("{stable}/10 combos with CV < 0.5"),
        ),
    ];

    Ok(ExperimentResult {
        id: "fig21",
        title: "Low-priority JCT timelines + CV under FIKIT sharing (Fig 21 / Table 3)",
        table,
        series,
        checks,
        notes: format!(
            "B inserts {inserts} tasks every {interval_ms}ms into A's continuous high-priority stream"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig21_table3_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 10);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
