//! Client ↔ scheduler wire protocol.
//!
//! The paper's hook client and FIKIT scheduler are separate processes
//! exchanging UDP messages. We keep that shape: small JSON frames with an
//! explicit version byte, so a fleet can roll the scheduler independently
//! of hook clients. JSON (not a binary codec) keeps frames inspectable
//! with tcpdump in production debugging — at the message rates involved
//! (one frame per kernel launch, ≤ tens of kHz) encoding cost is
//! irrelevant next to the network round trip.

use crate::core::{Dim3, Duration, Error, Priority, Result, SimTime, TaskId, TaskKey};
use crate::util::json::Json;

/// Protocol version; bumped on breaking changes.
pub const WIRE_VERSION: u8 = 1;

/// Messages sent by a hook client to the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// A service process registered with the scheduler.
    Register {
        task_key: TaskKey,
        priority: Priority,
        /// Whether the framework build exports kernel symbols
        /// (`-rdynamic`); without it the scheduler will keep the service
        /// in measurement-incapable degraded mode.
        has_symbols: bool,
    },
    /// A new task (invocation) of the service started.
    TaskStart { task_key: TaskKey, task_id: TaskId },
    /// An intercepted kernel launch, held by the hook pending a
    /// scheduler decision.
    Launch {
        task_key: TaskKey,
        task_id: TaskId,
        /// Resolved kernel function name (may be empty without symbols).
        kernel_name: String,
        grid: Dim3,
        block: Dim3,
        seq: u32,
        issued_at: SimTime,
    },
    /// The hook observed a kernel completion (end of a cudaEvent pair —
    /// only sent during the measurement stage or for holder kernels).
    Completion {
        task_key: TaskKey,
        task_id: TaskId,
        seq: u32,
        exec: Duration,
        finished_at: SimTime,
    },
    /// The current task of the service finished.
    TaskEnd { task_key: TaskKey, task_id: TaskId },
    /// Clean shutdown of the hook client.
    Disconnect { task_key: TaskKey },
}

/// Messages sent by the scheduler back to a hook client.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerMsg {
    /// Registration accepted; tells the client which stage to run in.
    Registered {
        task_key: TaskKey,
        /// True → the service has a ready profile and runs in sharing
        /// stage; false → measurement stage (exclusive + timing events).
        sharing_stage: bool,
    },
    /// Release the held launch `seq` to the GPU now.
    LaunchNow { task_key: TaskKey, task_id: TaskId, seq: u32 },
    /// Keep holding the launch (it is parked in a priority queue).
    Hold { task_key: TaskKey, task_id: TaskId, seq: u32 },
    /// Scheduler-side error (e.g. unknown task key).
    Error { message: String },
}

fn dim_to_json(d: Dim3) -> Json {
    Json::Arr(vec![Json::from(d.x as i64), Json::from(d.y as i64), Json::from(d.z as i64)])
}

fn dim_from_json(v: &Json) -> Result<Dim3> {
    let arr = v
        .as_arr()
        .filter(|a| a.len() == 3)
        .ok_or_else(|| Error::Protocol("dim3 must be a 3-array".into()))?;
    let g = |i: usize| -> Result<u32> {
        arr[i]
            .as_u64()
            .and_then(|v| u32::try_from(v).ok())
            .ok_or_else(|| Error::Protocol("dim3 element out of range".into()))
    };
    Ok(Dim3::new(g(0)?, g(1)?, g(2)?))
}

/// A framed message: 2-byte header (version, kind) + JSON body.
fn frame(kind: u8, body: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 2);
    out.push(WIRE_VERSION);
    out.push(kind);
    out.extend_from_slice(body.as_bytes());
    out
}

fn unframe(buf: &[u8]) -> Result<(u8, Json)> {
    if buf.len() < 2 {
        return Err(Error::Protocol("frame too short".into()));
    }
    if buf[0] != WIRE_VERSION {
        return Err(Error::Protocol(format!(
            "wire version mismatch: got {}, want {}",
            buf[0], WIRE_VERSION
        )));
    }
    let body = std::str::from_utf8(&buf[2..])
        .map_err(|_| Error::Protocol("frame body is not UTF-8".into()))?;
    Ok((buf[1], Json::parse(body)?))
}

const KIND_CLIENT: u8 = 0x01;
const KIND_SCHED: u8 = 0x02;

impl ClientMsg {
    fn to_json(&self) -> Json {
        match self {
            ClientMsg::Register {
                task_key,
                priority,
                has_symbols,
            } => Json::obj()
                .set("type", "register")
                .set("task_key", task_key.as_str())
                .set("priority", priority.to_string())
                .set("has_symbols", *has_symbols),
            ClientMsg::TaskStart { task_key, task_id } => Json::obj()
                .set("type", "task_start")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0),
            ClientMsg::Launch {
                task_key,
                task_id,
                kernel_name,
                grid,
                block,
                seq,
                issued_at,
            } => Json::obj()
                .set("type", "launch")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("kernel_name", kernel_name.as_str())
                .set("grid", dim_to_json(*grid))
                .set("block", dim_to_json(*block))
                .set("seq", *seq)
                .set("issued_at_ns", issued_at.nanos()),
            ClientMsg::Completion {
                task_key,
                task_id,
                seq,
                exec,
                finished_at,
            } => Json::obj()
                .set("type", "completion")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("seq", *seq)
                .set("exec_ns", exec.nanos())
                .set("finished_at_ns", finished_at.nanos()),
            ClientMsg::TaskEnd { task_key, task_id } => Json::obj()
                .set("type", "task_end")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0),
            ClientMsg::Disconnect { task_key } => Json::obj()
                .set("type", "disconnect")
                .set("task_key", task_key.as_str()),
        }
    }

    fn from_json(v: &Json) -> Result<ClientMsg> {
        let key = || -> Result<TaskKey> { Ok(TaskKey::new(v.req_str("task_key")?)) };
        let tid = || -> Result<TaskId> { Ok(TaskId(v.req_u64("task_id")?)) };
        match v.req_str("type")? {
            "register" => Ok(ClientMsg::Register {
                task_key: key()?,
                priority: v.req_str("priority")?.parse()?,
                has_symbols: v.req_bool("has_symbols")?,
            }),
            "task_start" => Ok(ClientMsg::TaskStart {
                task_key: key()?,
                task_id: tid()?,
            }),
            "launch" => Ok(ClientMsg::Launch {
                task_key: key()?,
                task_id: tid()?,
                kernel_name: v.req_str("kernel_name")?.to_string(),
                grid: dim_from_json(v.require("grid")?)?,
                block: dim_from_json(v.require("block")?)?,
                seq: v.req_u64("seq")? as u32,
                issued_at: SimTime(v.req_u64("issued_at_ns")?),
            }),
            "completion" => Ok(ClientMsg::Completion {
                task_key: key()?,
                task_id: tid()?,
                seq: v.req_u64("seq")? as u32,
                exec: Duration::from_nanos(v.req_u64("exec_ns")?),
                finished_at: SimTime(v.req_u64("finished_at_ns")?),
            }),
            "task_end" => Ok(ClientMsg::TaskEnd {
                task_key: key()?,
                task_id: tid()?,
            }),
            "disconnect" => Ok(ClientMsg::Disconnect { task_key: key()? }),
            other => Err(Error::Protocol(format!("unknown client msg type {other:?}"))),
        }
    }

    /// Encode to a datagram frame.
    pub fn encode(&self) -> Result<Vec<u8>> {
        Ok(frame(KIND_CLIENT, &self.to_json().encode()))
    }

    /// Decode from a datagram frame.
    pub fn decode(buf: &[u8]) -> Result<ClientMsg> {
        let (kind, body) = unframe(buf)?;
        if kind != KIND_CLIENT {
            return Err(Error::Protocol(format!(
                "expected client frame, got kind {kind}"
            )));
        }
        ClientMsg::from_json(&body)
    }
}

impl SchedulerMsg {
    fn to_json(&self) -> Json {
        match self {
            SchedulerMsg::Registered {
                task_key,
                sharing_stage,
            } => Json::obj()
                .set("type", "registered")
                .set("task_key", task_key.as_str())
                .set("sharing_stage", *sharing_stage),
            SchedulerMsg::LaunchNow {
                task_key,
                task_id,
                seq,
            } => Json::obj()
                .set("type", "launch_now")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("seq", *seq),
            SchedulerMsg::Hold {
                task_key,
                task_id,
                seq,
            } => Json::obj()
                .set("type", "hold")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("seq", *seq),
            SchedulerMsg::Error { message } => Json::obj()
                .set("type", "error")
                .set("message", message.as_str()),
        }
    }

    fn from_json(v: &Json) -> Result<SchedulerMsg> {
        let key = || -> Result<TaskKey> { Ok(TaskKey::new(v.req_str("task_key")?)) };
        match v.req_str("type")? {
            "registered" => Ok(SchedulerMsg::Registered {
                task_key: key()?,
                sharing_stage: v.req_bool("sharing_stage")?,
            }),
            "launch_now" => Ok(SchedulerMsg::LaunchNow {
                task_key: key()?,
                task_id: TaskId(v.req_u64("task_id")?),
                seq: v.req_u64("seq")? as u32,
            }),
            "hold" => Ok(SchedulerMsg::Hold {
                task_key: key()?,
                task_id: TaskId(v.req_u64("task_id")?),
                seq: v.req_u64("seq")? as u32,
            }),
            "error" => Ok(SchedulerMsg::Error {
                message: v.req_str("message")?.to_string(),
            }),
            other => Err(Error::Protocol(format!(
                "unknown scheduler msg type {other:?}"
            ))),
        }
    }

    pub fn encode(&self) -> Result<Vec<u8>> {
        Ok(frame(KIND_SCHED, &self.to_json().encode()))
    }

    pub fn decode(buf: &[u8]) -> Result<SchedulerMsg> {
        let (kind, body) = unframe(buf)?;
        if kind != KIND_SCHED {
            return Err(Error::Protocol(format!(
                "expected scheduler frame, got kind {kind}"
            )));
        }
        SchedulerMsg::from_json(&body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_msg_round_trip() {
        let msgs = vec![
            ClientMsg::Register {
                task_key: TaskKey::new("svc"),
                priority: Priority::P3,
                has_symbols: true,
            },
            ClientMsg::TaskStart {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(9),
            },
            ClientMsg::Launch {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(7),
                kernel_name: "gemm<float, 128>".into(),
                grid: Dim3::new(64, 2, 1),
                block: Dim3::new(256, 1, 1),
                seq: 12,
                issued_at: SimTime(999),
            },
            ClientMsg::Completion {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(7),
                seq: 12,
                exec: Duration::from_micros(120),
                finished_at: SimTime(1_999),
            },
            ClientMsg::TaskEnd {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(7),
            },
            ClientMsg::Disconnect {
                task_key: TaskKey::new("svc"),
            },
        ];
        for msg in msgs {
            let enc = msg.encode().unwrap();
            assert_eq!(enc[0], WIRE_VERSION);
            let dec = ClientMsg::decode(&enc).unwrap();
            assert_eq!(dec, msg);
        }
    }

    #[test]
    fn scheduler_msg_round_trip() {
        let msgs = vec![
            SchedulerMsg::Registered {
                task_key: TaskKey::new("svc"),
                sharing_stage: true,
            },
            SchedulerMsg::LaunchNow {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(1),
                seq: 3,
            },
            SchedulerMsg::Hold {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(1),
                seq: 3,
            },
            SchedulerMsg::Error {
                message: "unknown task".into(),
            },
        ];
        for msg in msgs {
            let dec = SchedulerMsg::decode(&msg.encode().unwrap()).unwrap();
            assert_eq!(dec, msg);
        }
    }

    #[test]
    fn kind_and_version_enforced() {
        let msg = ClientMsg::Disconnect {
            task_key: TaskKey::new("svc"),
        };
        let mut enc = msg.encode().unwrap();
        // Wrong kind routing is rejected.
        assert!(SchedulerMsg::decode(&enc).is_err());
        // Version mismatch is rejected.
        enc[0] = 99;
        assert!(ClientMsg::decode(&enc).is_err());
        // Truncated frames are rejected.
        assert!(ClientMsg::decode(&[1]).is_err());
        // Corrupt body is rejected.
        assert!(ClientMsg::decode(&[WIRE_VERSION, 0x01, b'{']).is_err());
    }
}
