//! Client ↔ scheduler wire protocol.
//!
//! The paper's hook client and FIKIT scheduler are separate processes
//! exchanging UDP messages. We keep that shape: small JSON frames with an
//! explicit version byte, so a fleet can roll the scheduler independently
//! of hook clients. JSON (not a binary codec) keeps frames inspectable
//! with tcpdump in production debugging — at the message rates involved
//! (one frame per kernel launch, ≤ tens of kHz) encoding cost is
//! irrelevant next to the network round trip.
//!
//! ## Version 2: the loss-tolerant envelope (DESIGN.md §Daemon)
//!
//! UDP drops datagrams, so v2 makes every client message safely
//! *retransmittable*:
//!
//! * every client frame carries a per-client monotonic `msg_seq`; the
//!   daemon remembers the last `msg_seq` it processed per client and
//!   answers a retransmit (same `msg_seq`) by **resending the cached
//!   reply without re-executing side effects** — duplicate `Register`,
//!   `Launch`, `TaskStart` and `Completion` frames are idempotent;
//! * fire-and-forget messages are gone: lifecycle messages are
//!   acknowledged with [`SchedulerMsg::Ack`] echoing the `msg_seq`, so
//!   the client's bounded-retry loop knows when to stop;
//! * a client whose deferred `LaunchNow` was itself dropped recovers by
//!   polling with [`ClientMsg::ReleaseQuery`] — the daemon answers from
//!   its released-sequence record (`LaunchNow` if already released,
//!   `Hold` if still parked).
//!
//! v1 frames (no `msg_seq`) are rejected by the version byte.
//!
//! ## Version 3: the federation control plane (DESIGN.md §Fleet-federation)
//!
//! v3 adds the fleet control plane on top of the v2 envelope (which is
//! carried unchanged):
//!
//! * a `Register` against a full node is no longer a bare `Error` — the
//!   daemon answers [`SchedulerMsg::Redirect`] (a named live peer has
//!   room; go there) or [`SchedulerMsg::RetryAfter`] (the whole visible
//!   fleet is full; back off for an explicit number of milliseconds).
//!   Load is shed with a reason, never queued unboundedly;
//! * nodes gossip capacity/health to each other with
//!   [`PeerMsg::Beacon`] frames (`KIND_PEER`), which ride the same
//!   datagram socket as client traffic but are routed by the frame kind
//!   byte and **never enter the session journal** — replay determinism
//!   (ADR-004) is untouched by the control plane.
//!
//! v2 frames are rejected by the version byte: the fleet rolls the
//! scheduler and hooks together per the deployment story in ADR-005.

use crate::core::{Dim3, Duration, Error, Priority, Result, SimTime, TaskId, TaskKey};
use crate::util::json::Json;

/// Protocol version; bumped on breaking changes. v2 added the
/// `msg_seq` retransmit envelope, `Ack` and `ReleaseQuery`; v3 added
/// the federation control plane (`Redirect`, `RetryAfter`, peer
/// `Beacon` frames).
pub const WIRE_VERSION: u8 = 3;

/// Messages sent by a hook client to the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// A service process registered with the scheduler.
    Register {
        task_key: TaskKey,
        priority: Priority,
        /// Whether the framework build exports kernel symbols
        /// (`-rdynamic`); without it the scheduler will keep the service
        /// in measurement-incapable degraded mode.
        has_symbols: bool,
        /// Optional model name hint (`fikit list-models` vocabulary).
        /// The daemon's registry uses it for compatibility-aware shard
        /// placement; absent → a neutral default demand profile.
        model: Option<String>,
    },
    /// A new task (invocation) of the service started.
    TaskStart { task_key: TaskKey, task_id: TaskId },
    /// An intercepted kernel launch, held by the hook pending a
    /// scheduler decision.
    Launch {
        task_key: TaskKey,
        task_id: TaskId,
        /// Resolved kernel function name (may be empty without symbols).
        kernel_name: String,
        grid: Dim3,
        block: Dim3,
        seq: u32,
        issued_at: SimTime,
    },
    /// The hook observed a kernel completion (end of a cudaEvent pair —
    /// only sent during the measurement stage or for holder kernels).
    Completion {
        task_key: TaskKey,
        task_id: TaskId,
        seq: u32,
        exec: Duration,
        finished_at: SimTime,
    },
    /// A released fill kernel was preempted device-side before (or
    /// while) running (ADR-007): the hook re-holds it and asks the
    /// scheduler to re-park the launch, indexed by its remaining
    /// duration (`remaining` = full duration for a whole eviction, the
    /// unexecuted suffix for a split remnant).
    Preempted {
        task_key: TaskKey,
        task_id: TaskId,
        /// Resolved kernel function name (may be empty without symbols).
        kernel_name: String,
        grid: Dim3,
        block: Dim3,
        seq: u32,
        remaining: Duration,
    },
    /// The current task of the service finished.
    TaskEnd { task_key: TaskKey, task_id: TaskId },
    /// Clean shutdown of the hook client.
    Disconnect { task_key: TaskKey },
    /// Loss-recovery poll: "was my held launch `seq` released yet?"
    /// Pure query — the daemon answers `LaunchNow` (already released),
    /// `Hold` (still parked) or `Error` (unknown launch) without side
    /// effects, so a client whose deferred release datagram was dropped
    /// can converge instead of blocking forever.
    ReleaseQuery { task_key: TaskKey, seq: u32 },
}

impl ClientMsg {
    /// The service this message belongs to (every variant carries one —
    /// the daemon routes on it).
    pub fn task_key(&self) -> &TaskKey {
        match self {
            ClientMsg::Register { task_key, .. }
            | ClientMsg::TaskStart { task_key, .. }
            | ClientMsg::Launch { task_key, .. }
            | ClientMsg::Completion { task_key, .. }
            | ClientMsg::Preempted { task_key, .. }
            | ClientMsg::TaskEnd { task_key, .. }
            | ClientMsg::Disconnect { task_key }
            | ClientMsg::ReleaseQuery { task_key, .. } => task_key,
        }
    }
}

/// Messages sent by the scheduler back to a hook client.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerMsg {
    /// Registration accepted; tells the client which stage to run in.
    Registered {
        task_key: TaskKey,
        /// True → the service has a ready profile and runs in sharing
        /// stage; false → measurement stage (exclusive + timing events).
        sharing_stage: bool,
    },
    /// Release the held launch `seq` to the GPU now.
    LaunchNow { task_key: TaskKey, task_id: TaskId, seq: u32 },
    /// Keep holding the launch (it is parked in a priority queue).
    Hold { task_key: TaskKey, task_id: TaskId, seq: u32 },
    /// Acknowledge a lifecycle message (`TaskStart`/`Completion`/
    /// `TaskEnd`/`Disconnect`), echoing its `msg_seq` so the client's
    /// bounded-retry loop can stop retransmitting.
    Ack { msg_seq: u64 },
    /// Scheduler-side error (e.g. unknown task key).
    Error { message: String },
    /// This node is at capacity but the named live peer has room:
    /// re-register there. Answers a `Register` only; the client follows
    /// the redirect transparently (its next `Register` goes to `node`
    /// with a fresh session).
    Redirect { task_key: TaskKey, node: String },
    /// Explicit load shed: every node this one can see is full (or no
    /// peer is live). The client should surface the reason and may retry
    /// after `ms` milliseconds — the daemon never queues admissions
    /// unboundedly.
    RetryAfter {
        task_key: TaskKey,
        ms: u64,
        reason: String,
    },
}

/// Node-to-node control-plane messages (frame kind `KIND_PEER`).
///
/// Beacons are gossip, not state: they are unacknowledged,
/// loss-tolerant, and deduplicated by a per-node monotonic `seq` so
/// duplicated/reordered/delayed deliveries over a lossy fabric can
/// never regress a peer's `FleetView` entry (DESIGN.md §Fleet-federation).
#[derive(Debug, Clone, PartialEq)]
pub enum PeerMsg {
    /// Periodic capacity/health advertisement from one node.
    Beacon {
        /// Advertised node name (the same name `Redirect` carries).
        node: String,
        /// Per-node monotonic beacon sequence; stale (`<=` last seen)
        /// beacons are dropped by the receiver.
        seq: u64,
        /// Sender's clock at emission, for observability only —
        /// liveness uses receiver-local arrival times.
        sent_at_ns: u64,
        /// Device count and per-device capacity of the sender…
        devices: u32,
        capacity: u32,
        /// …and how many of those `devices × capacity` slots are taken.
        residents: u32,
        /// True while the node is draining for shutdown: it stays alive
        /// in fleet views but must not receive redirects.
        draining: bool,
    },
}

fn dim_to_json(d: Dim3) -> Json {
    Json::Arr(vec![Json::from(d.x as i64), Json::from(d.y as i64), Json::from(d.z as i64)])
}

fn dim_from_json(v: &Json) -> Result<Dim3> {
    let arr = v
        .as_arr()
        .filter(|a| a.len() == 3)
        .ok_or_else(|| Error::Protocol("dim3 must be a 3-array".into()))?;
    let g = |i: usize| -> Result<u32> {
        arr[i]
            .as_u64()
            .and_then(|v| u32::try_from(v).ok())
            .ok_or_else(|| Error::Protocol("dim3 element out of range".into()))
    };
    Ok(Dim3::new(g(0)?, g(1)?, g(2)?))
}

/// A framed message: 2-byte header (version, kind) + JSON body.
fn frame(kind: u8, body: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 2);
    out.push(WIRE_VERSION);
    out.push(kind);
    out.extend_from_slice(body.as_bytes());
    out
}

fn unframe(buf: &[u8]) -> Result<(u8, Json)> {
    if buf.len() < 2 {
        return Err(Error::Protocol("frame too short".into()));
    }
    if buf[0] != WIRE_VERSION {
        return Err(Error::Protocol(format!(
            "wire version mismatch: got {}, want {}",
            buf[0], WIRE_VERSION
        )));
    }
    let body = std::str::from_utf8(&buf[2..])
        .map_err(|_| Error::Protocol("frame body is not UTF-8".into()))?;
    Ok((buf[1], Json::parse(body)?))
}

const KIND_CLIENT: u8 = 0x01;
const KIND_SCHED: u8 = 0x02;
/// Node-to-node control-plane frames ([`PeerMsg`]). Public so the
/// daemon's datagram loop can route on the kind byte without a decode
/// attempt per possible kind.
pub const KIND_PEER: u8 = 0x03;

/// Cheap peek at a frame's kind byte (`None` for runts). The daemon
/// uses this to fork peer control-plane frames away from the journaled
/// client path before any JSON is parsed.
pub fn frame_kind(buf: &[u8]) -> Option<u8> {
    if buf.len() < 2 {
        None
    } else {
        Some(buf[1])
    }
}

impl ClientMsg {
    /// JSON body (no envelope). `pub(crate)` so the daemon's session
    /// journal can persist decoded messages verbatim (DESIGN.md §Daemon).
    pub(crate) fn to_json(&self) -> Json {
        match self {
            ClientMsg::Register {
                task_key,
                priority,
                has_symbols,
                model,
            } => {
                let j = Json::obj()
                    .set("type", "register")
                    .set("task_key", task_key.as_str())
                    .set("priority", priority.to_string())
                    .set("has_symbols", *has_symbols);
                match model {
                    Some(m) => j.set("model", m.as_str()),
                    None => j,
                }
            }
            ClientMsg::TaskStart { task_key, task_id } => Json::obj()
                .set("type", "task_start")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0),
            ClientMsg::Launch {
                task_key,
                task_id,
                kernel_name,
                grid,
                block,
                seq,
                issued_at,
            } => Json::obj()
                .set("type", "launch")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("kernel_name", kernel_name.as_str())
                .set("grid", dim_to_json(*grid))
                .set("block", dim_to_json(*block))
                .set("seq", *seq)
                .set("issued_at_ns", issued_at.nanos()),
            ClientMsg::Completion {
                task_key,
                task_id,
                seq,
                exec,
                finished_at,
            } => Json::obj()
                .set("type", "completion")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("seq", *seq)
                .set("exec_ns", exec.nanos())
                .set("finished_at_ns", finished_at.nanos()),
            ClientMsg::Preempted {
                task_key,
                task_id,
                kernel_name,
                grid,
                block,
                seq,
                remaining,
            } => Json::obj()
                .set("type", "preempted")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("kernel_name", kernel_name.as_str())
                .set("grid", dim_to_json(*grid))
                .set("block", dim_to_json(*block))
                .set("seq", *seq)
                .set("remaining_ns", remaining.nanos()),
            ClientMsg::TaskEnd { task_key, task_id } => Json::obj()
                .set("type", "task_end")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0),
            ClientMsg::Disconnect { task_key } => Json::obj()
                .set("type", "disconnect")
                .set("task_key", task_key.as_str()),
            ClientMsg::ReleaseQuery { task_key, seq } => Json::obj()
                .set("type", "release_query")
                .set("task_key", task_key.as_str())
                .set("seq", *seq),
        }
    }

    pub(crate) fn from_json(v: &Json) -> Result<ClientMsg> {
        let key = || -> Result<TaskKey> { Ok(TaskKey::new(v.req_str("task_key")?)) };
        let tid = || -> Result<TaskId> { Ok(TaskId(v.req_u64("task_id")?)) };
        match v.req_str("type")? {
            "register" => Ok(ClientMsg::Register {
                task_key: key()?,
                priority: v.req_str("priority")?.parse()?,
                has_symbols: v.req_bool("has_symbols")?,
                model: v
                    .require("model")
                    .ok()
                    .and_then(|m| m.as_str())
                    .map(str::to_string),
            }),
            "task_start" => Ok(ClientMsg::TaskStart {
                task_key: key()?,
                task_id: tid()?,
            }),
            "launch" => Ok(ClientMsg::Launch {
                task_key: key()?,
                task_id: tid()?,
                kernel_name: v.req_str("kernel_name")?.to_string(),
                grid: dim_from_json(v.require("grid")?)?,
                block: dim_from_json(v.require("block")?)?,
                seq: v.req_u64("seq")? as u32,
                issued_at: SimTime(v.req_u64("issued_at_ns")?),
            }),
            "completion" => Ok(ClientMsg::Completion {
                task_key: key()?,
                task_id: tid()?,
                seq: v.req_u64("seq")? as u32,
                exec: Duration::from_nanos(v.req_u64("exec_ns")?),
                finished_at: SimTime(v.req_u64("finished_at_ns")?),
            }),
            "preempted" => Ok(ClientMsg::Preempted {
                task_key: key()?,
                task_id: tid()?,
                kernel_name: v.req_str("kernel_name")?.to_string(),
                grid: dim_from_json(v.require("grid")?)?,
                block: dim_from_json(v.require("block")?)?,
                seq: v.req_u64("seq")? as u32,
                remaining: Duration::from_nanos(v.req_u64("remaining_ns")?),
            }),
            "task_end" => Ok(ClientMsg::TaskEnd {
                task_key: key()?,
                task_id: tid()?,
            }),
            "disconnect" => Ok(ClientMsg::Disconnect { task_key: key()? }),
            "release_query" => Ok(ClientMsg::ReleaseQuery {
                task_key: key()?,
                seq: v.req_u64("seq")? as u32,
            }),
            other => Err(Error::Protocol(format!("unknown client msg type {other:?}"))),
        }
    }

    /// Encode to a datagram frame carrying the retransmit envelope.
    /// Retransmits MUST reuse the same bytes (same `msg_seq`) so the
    /// daemon can recognize them.
    pub fn encode_seq(&self, msg_seq: u64) -> Result<Vec<u8>> {
        Ok(frame(
            KIND_CLIENT,
            &self.to_json().set("msg_seq", msg_seq).encode(),
        ))
    }

    /// Encode without a meaningful sequence (tests / one-shot tools).
    pub fn encode(&self) -> Result<Vec<u8>> {
        self.encode_seq(0)
    }

    /// Decode a datagram frame into `(msg_seq, message)`.
    pub fn decode_seq(buf: &[u8]) -> Result<(u64, ClientMsg)> {
        let (kind, body) = unframe(buf)?;
        if kind != KIND_CLIENT {
            return Err(Error::Protocol(format!(
                "expected client frame, got kind {kind}"
            )));
        }
        Ok((body.req_u64("msg_seq")?, ClientMsg::from_json(&body)?))
    }

    /// Decode, discarding the envelope (tests / inspection).
    pub fn decode(buf: &[u8]) -> Result<ClientMsg> {
        ClientMsg::decode_seq(buf).map(|(_, m)| m)
    }
}

impl SchedulerMsg {
    /// JSON body (no envelope). `pub(crate)` so journal snapshots can
    /// persist each client's cached replies for post-restart dedup.
    pub(crate) fn to_json(&self) -> Json {
        match self {
            SchedulerMsg::Registered {
                task_key,
                sharing_stage,
            } => Json::obj()
                .set("type", "registered")
                .set("task_key", task_key.as_str())
                .set("sharing_stage", *sharing_stage),
            SchedulerMsg::LaunchNow {
                task_key,
                task_id,
                seq,
            } => Json::obj()
                .set("type", "launch_now")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("seq", *seq),
            SchedulerMsg::Hold {
                task_key,
                task_id,
                seq,
            } => Json::obj()
                .set("type", "hold")
                .set("task_key", task_key.as_str())
                .set("task_id", task_id.0)
                .set("seq", *seq),
            SchedulerMsg::Ack { msg_seq } => {
                Json::obj().set("type", "ack").set("msg_seq", *msg_seq)
            }
            SchedulerMsg::Error { message } => Json::obj()
                .set("type", "error")
                .set("message", message.as_str()),
            SchedulerMsg::Redirect { task_key, node } => Json::obj()
                .set("type", "redirect")
                .set("task_key", task_key.as_str())
                .set("node", node.as_str()),
            SchedulerMsg::RetryAfter {
                task_key,
                ms,
                reason,
            } => Json::obj()
                .set("type", "retry_after")
                .set("task_key", task_key.as_str())
                .set("ms", *ms)
                .set("reason", reason.as_str()),
        }
    }

    pub(crate) fn from_json(v: &Json) -> Result<SchedulerMsg> {
        let key = || -> Result<TaskKey> { Ok(TaskKey::new(v.req_str("task_key")?)) };
        match v.req_str("type")? {
            "registered" => Ok(SchedulerMsg::Registered {
                task_key: key()?,
                sharing_stage: v.req_bool("sharing_stage")?,
            }),
            "launch_now" => Ok(SchedulerMsg::LaunchNow {
                task_key: key()?,
                task_id: TaskId(v.req_u64("task_id")?),
                seq: v.req_u64("seq")? as u32,
            }),
            "hold" => Ok(SchedulerMsg::Hold {
                task_key: key()?,
                task_id: TaskId(v.req_u64("task_id")?),
                seq: v.req_u64("seq")? as u32,
            }),
            "ack" => Ok(SchedulerMsg::Ack {
                msg_seq: v.req_u64("msg_seq")?,
            }),
            "error" => Ok(SchedulerMsg::Error {
                message: v.req_str("message")?.to_string(),
            }),
            "redirect" => Ok(SchedulerMsg::Redirect {
                task_key: key()?,
                node: v.req_str("node")?.to_string(),
            }),
            "retry_after" => Ok(SchedulerMsg::RetryAfter {
                task_key: key()?,
                ms: v.req_u64("ms")?,
                reason: v.req_str("reason")?.to_string(),
            }),
            other => Err(Error::Protocol(format!(
                "unknown scheduler msg type {other:?}"
            ))),
        }
    }

    pub fn encode(&self) -> Result<Vec<u8>> {
        Ok(frame(KIND_SCHED, &self.to_json().encode()))
    }

    pub fn decode(buf: &[u8]) -> Result<SchedulerMsg> {
        let (kind, body) = unframe(buf)?;
        if kind != KIND_SCHED {
            return Err(Error::Protocol(format!(
                "expected scheduler frame, got kind {kind}"
            )));
        }
        SchedulerMsg::from_json(&body)
    }
}

impl PeerMsg {
    fn to_json(&self) -> Json {
        match self {
            PeerMsg::Beacon {
                node,
                seq,
                sent_at_ns,
                devices,
                capacity,
                residents,
                draining,
            } => Json::obj()
                .set("type", "beacon")
                .set("node", node.as_str())
                .set("seq", *seq)
                .set("sent_at_ns", *sent_at_ns)
                .set("devices", *devices)
                .set("capacity", *capacity)
                .set("residents", *residents)
                .set("draining", *draining),
        }
    }

    fn from_json(v: &Json) -> Result<PeerMsg> {
        match v.req_str("type")? {
            "beacon" => Ok(PeerMsg::Beacon {
                node: v.req_str("node")?.to_string(),
                seq: v.req_u64("seq")?,
                sent_at_ns: v.req_u64("sent_at_ns")?,
                devices: v.req_u64("devices")? as u32,
                capacity: v.req_u64("capacity")? as u32,
                residents: v.req_u64("residents")? as u32,
                draining: v.req_bool("draining")?,
            }),
            other => Err(Error::Protocol(format!("unknown peer msg type {other:?}"))),
        }
    }

    pub fn encode(&self) -> Result<Vec<u8>> {
        Ok(frame(KIND_PEER, &self.to_json().encode()))
    }

    pub fn decode(buf: &[u8]) -> Result<PeerMsg> {
        let (kind, body) = unframe(buf)?;
        if kind != KIND_PEER {
            return Err(Error::Protocol(format!(
                "expected peer frame, got kind {kind}"
            )));
        }
        PeerMsg::from_json(&body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_msg_round_trip() {
        let msgs = vec![
            ClientMsg::Register {
                task_key: TaskKey::new("svc"),
                priority: Priority::P3,
                has_symbols: true,
                model: Some("resnet50".into()),
            },
            ClientMsg::Register {
                task_key: TaskKey::new("svc"),
                priority: Priority::P3,
                has_symbols: true,
                model: None,
            },
            ClientMsg::TaskStart {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(9),
            },
            ClientMsg::Launch {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(7),
                kernel_name: "gemm<float, 128>".into(),
                grid: Dim3::new(64, 2, 1),
                block: Dim3::new(256, 1, 1),
                seq: 12,
                issued_at: SimTime(999),
            },
            ClientMsg::Completion {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(7),
                seq: 12,
                exec: Duration::from_micros(120),
                finished_at: SimTime(1_999),
            },
            ClientMsg::Preempted {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(7),
                kernel_name: "gemm<float, 128>".into(),
                grid: Dim3::new(64, 2, 1),
                block: Dim3::new(256, 1, 1),
                seq: 12,
                remaining: Duration::from_micros(80),
            },
            ClientMsg::TaskEnd {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(7),
            },
            ClientMsg::Disconnect {
                task_key: TaskKey::new("svc"),
            },
            ClientMsg::ReleaseQuery {
                task_key: TaskKey::new("svc"),
                seq: 41,
            },
        ];
        for (i, msg) in msgs.into_iter().enumerate() {
            let enc = msg.encode_seq(i as u64 + 1).unwrap();
            assert_eq!(enc[0], WIRE_VERSION);
            let (msg_seq, dec) = ClientMsg::decode_seq(&enc).unwrap();
            assert_eq!(msg_seq, i as u64 + 1, "envelope survives the round trip");
            assert_eq!(dec, msg);
            assert_eq!(dec.task_key(), &TaskKey::new("svc"));
        }
    }

    #[test]
    fn retransmits_are_byte_identical_and_seq_is_required() {
        let msg = ClientMsg::TaskStart {
            task_key: TaskKey::new("svc"),
            task_id: TaskId(1),
        };
        // Same msg_seq → same bytes: the retransmit invariant the
        // daemon's dedup relies on.
        assert_eq!(msg.encode_seq(7).unwrap(), msg.encode_seq(7).unwrap());
        assert_ne!(msg.encode_seq(7).unwrap(), msg.encode_seq(8).unwrap());
        // A v2 frame without the envelope is rejected.
        let bare = frame(KIND_CLIENT, &msg.to_json().encode());
        assert!(ClientMsg::decode_seq(&bare).is_err());
    }

    #[test]
    fn scheduler_msg_round_trip() {
        let msgs = vec![
            SchedulerMsg::Registered {
                task_key: TaskKey::new("svc"),
                sharing_stage: true,
            },
            SchedulerMsg::LaunchNow {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(1),
                seq: 3,
            },
            SchedulerMsg::Hold {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(1),
                seq: 3,
            },
            SchedulerMsg::Ack { msg_seq: 99 },
            SchedulerMsg::Error {
                message: "unknown task".into(),
            },
            SchedulerMsg::Redirect {
                task_key: TaskKey::new("svc"),
                node: "n2".into(),
            },
            SchedulerMsg::RetryAfter {
                task_key: TaskKey::new("svc"),
                ms: 250,
                reason: "fleet at capacity".into(),
            },
        ];
        for msg in msgs {
            let dec = SchedulerMsg::decode(&msg.encode().unwrap()).unwrap();
            assert_eq!(dec, msg);
        }
    }

    #[test]
    fn peer_beacon_round_trip_and_kind_routing() {
        let b = PeerMsg::Beacon {
            node: "n1".into(),
            seq: 42,
            sent_at_ns: 1_000_000,
            devices: 2,
            capacity: 16,
            residents: 7,
            draining: false,
        };
        let enc = b.encode().unwrap();
        assert_eq!(enc[0], WIRE_VERSION);
        assert_eq!(frame_kind(&enc), Some(KIND_PEER));
        assert_eq!(PeerMsg::decode(&enc).unwrap(), b);
        // Peer frames are not client or scheduler frames.
        assert!(ClientMsg::decode(&enc).is_err());
        assert!(SchedulerMsg::decode(&enc).is_err());
        // And the kind peek handles runts.
        assert_eq!(frame_kind(&[WIRE_VERSION]), None);
    }

    #[test]
    fn kind_and_version_enforced() {
        let msg = ClientMsg::Disconnect {
            task_key: TaskKey::new("svc"),
        };
        let mut enc = msg.encode().unwrap();
        // Wrong kind routing is rejected.
        assert!(SchedulerMsg::decode(&enc).is_err());
        // Version mismatch is rejected.
        enc[0] = 99;
        assert!(ClientMsg::decode(&enc).is_err());
        // Truncated frames are rejected.
        assert!(ClientMsg::decode(&[1]).is_err());
        // Corrupt body is rejected.
        assert!(ClientMsg::decode(&[WIRE_VERSION, 0x01, b'{']).is_err());
    }
}
