//! Datagram transports for the hook↔scheduler protocol.
//!
//! Two interchangeable implementations:
//!
//! * [`ChannelTransport`] — an in-process crossbeam channel pair.
//!   Deterministic and allocation-cheap; used by tests and by the
//!   real-time engine when client and scheduler share a process.
//! * [`UdpTransport`] — real UDP sockets, the paper's deployment shape
//!   (hook clients and the scheduler may sit on different machines).

use crate::core::{Error, Result};
use std::net::UdpSocket;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;
use std::time::Duration as StdDuration;

/// A bidirectional datagram endpoint.
pub trait Transport: Send {
    /// Send one datagram to the peer.
    fn send(&self, buf: &[u8]) -> Result<()>;
    /// Receive one datagram, waiting up to `timeout`. `Ok(None)` on
    /// timeout.
    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>>;
}

/// In-process channel transport. [`ChannelTransport::pair`] yields two
/// connected endpoints. The receiver sits behind a mutex so the endpoint
/// is `Sync` (std mpsc receivers are not).
pub struct ChannelTransport {
    tx: SyncSender<Vec<u8>>,
    rx: Mutex<Receiver<Vec<u8>>>,
}

impl ChannelTransport {
    /// Create a connected (client, server) endpoint pair.
    pub fn pair() -> (ChannelTransport, ChannelTransport) {
        let (a_tx, a_rx) = sync_channel(4096);
        let (b_tx, b_rx) = sync_channel(4096);
        (
            ChannelTransport {
                tx: a_tx,
                rx: Mutex::new(b_rx),
            },
            ChannelTransport {
                tx: b_tx,
                rx: Mutex::new(a_rx),
            },
        )
    }
}

impl Transport for ChannelTransport {
    fn send(&self, buf: &[u8]) -> Result<()> {
        self.tx
            .send(buf.to_vec())
            .map_err(|_| Error::Protocol("peer disconnected".into()))
    }

    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>> {
        let rx = self.rx.lock().expect("transport mutex poisoned");
        match rx.recv_timeout(timeout) {
            Ok(buf) => Ok(Some(buf)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Protocol("peer disconnected".into()))
            }
        }
    }
}

/// Blocking UDP transport (client side; the scheduler daemon uses tokio,
/// see [`crate::server`]).
pub struct UdpTransport {
    socket: UdpSocket,
}

impl UdpTransport {
    /// Bind an ephemeral local port and connect to the scheduler address.
    pub fn connect(scheduler_addr: &str) -> Result<UdpTransport> {
        let socket = UdpSocket::bind("0.0.0.0:0")?;
        socket.connect(scheduler_addr)?;
        Ok(UdpTransport { socket })
    }

    /// Local address (tests).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.socket.local_addr()?)
    }
}

impl Transport for UdpTransport {
    fn send(&self, buf: &[u8]) -> Result<()> {
        self.socket.send(buf)?;
        Ok(())
    }

    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>> {
        self.socket.set_read_timeout(Some(timeout))?;
        let mut buf = vec![0u8; 64 * 1024];
        match self.socket.recv(&mut buf) {
            Ok(n) => {
                buf.truncate(n);
                Ok(Some(buf))
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(None)
            }
            Err(e) => Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_pair_round_trip() {
        let (client, server) = ChannelTransport::pair();
        client.send(b"hello").unwrap();
        let got = server.recv(StdDuration::from_millis(100)).unwrap().unwrap();
        assert_eq!(got, b"hello");
        server.send(b"world").unwrap();
        let got = client.recv(StdDuration::from_millis(100)).unwrap().unwrap();
        assert_eq!(got, b"world");
    }

    #[test]
    fn channel_recv_times_out() {
        let (client, _server) = ChannelTransport::pair();
        let got = client.recv(StdDuration::from_millis(10)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn udp_loopback_round_trip() {
        let server = UdpSocket::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let client = UdpTransport::connect(&addr.to_string()).unwrap();

        client.send(b"ping").unwrap();
        let mut buf = [0u8; 64];
        let (n, from) = server.recv_from(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");

        server.send_to(b"pong", from).unwrap();
        let got = client.recv(StdDuration::from_millis(200)).unwrap().unwrap();
        assert_eq!(got, b"pong");
    }
}
