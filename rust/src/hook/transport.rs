//! Datagram transports for the hook↔scheduler protocol.
//!
//! Client-side endpoints (the [`Transport`] trait):
//!
//! * [`ChannelTransport`] — an in-process channel pair. Deterministic
//!   and allocation-cheap; used by tests and by single-process setups.
//! * [`UdpTransport`] — real UDP sockets, the paper's deployment shape
//!   (hook clients and the scheduler may sit on different machines).
//! * [`LossyTransport`] — a client endpoint on a [`LossyNet`], the
//!   deterministic lossy in-process fabric the daemon's loss-recovery
//!   tests run on (DESIGN.md §Daemon).
//!
//! Daemon-side endpoints (the [`ServerTransport`] trait) mirror UDP's
//! `recv_from`/`send_to` shape: [`UdpServerTransport`] for real sockets
//! and [`LossyNet::server_endpoint`] for the in-process fabric.

use crate::core::{Error, Result};
use crate::util::rng::Rng;
use std::cell::Cell;
use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, UdpSocket};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration as StdDuration, Instant};

/// A bidirectional datagram endpoint.
pub trait Transport: Send {
    /// Send one datagram to the peer.
    fn send(&self, buf: &[u8]) -> Result<()>;
    /// Receive one datagram, waiting up to `timeout`. `Ok(None)` on
    /// timeout.
    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>>;
}

/// A daemon-side datagram endpoint serving many clients: datagrams come
/// with a reply address, and replies are addressed explicitly.
pub trait ServerTransport: Send {
    /// Receive one datagram and its sender, waiting up to `timeout`.
    /// `Ok(None)` on timeout.
    fn recv_from(&self, timeout: StdDuration) -> Result<Option<(Vec<u8>, SocketAddr)>>;
    /// Send one datagram to `addr`. Datagram semantics: best-effort,
    /// errors on unreachable peers may be swallowed.
    fn send_to(&self, buf: &[u8], addr: SocketAddr) -> Result<()>;
}

/// In-process channel transport. [`ChannelTransport::pair`] yields two
/// connected endpoints. The receiver sits behind a mutex so the endpoint
/// is `Sync` (std mpsc receivers are not).
pub struct ChannelTransport {
    tx: SyncSender<Vec<u8>>,
    rx: Mutex<Receiver<Vec<u8>>>,
}

impl ChannelTransport {
    /// Create a connected (client, server) endpoint pair.
    pub fn pair() -> (ChannelTransport, ChannelTransport) {
        let (a_tx, a_rx) = sync_channel(4096);
        let (b_tx, b_rx) = sync_channel(4096);
        (
            ChannelTransport {
                tx: a_tx,
                rx: Mutex::new(b_rx),
            },
            ChannelTransport {
                tx: b_tx,
                rx: Mutex::new(a_rx),
            },
        )
    }
}

impl Transport for ChannelTransport {
    fn send(&self, buf: &[u8]) -> Result<()> {
        self.tx
            .send(buf.to_vec())
            .map_err(|_| Error::Protocol("peer disconnected".into()))
    }

    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>> {
        let rx = self.rx.lock().expect("transport mutex poisoned");
        match rx.recv_timeout(timeout) {
            Ok(buf) => Ok(Some(buf)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Protocol("peer disconnected".into()))
            }
        }
    }
}

/// Maximum datagram we ever expect (messages are small JSON frames; this
/// is headroom, not a protocol limit).
const RECV_BUF_LEN: usize = 64 * 1024;

/// Shared recv-side caching for both UDP endpoints: the last applied
/// read timeout (so `set_read_timeout` — a syscall — is only re-issued
/// when the timeout actually changes) and a reusable scratch buffer (so
/// each call allocates only the returned payload, not a fresh 64 KiB
/// buffer).
struct CachedUdpSocket {
    socket: UdpSocket,
    applied_timeout: Cell<Option<StdDuration>>,
    recv_buf: Mutex<Vec<u8>>,
}

impl CachedUdpSocket {
    fn new(socket: UdpSocket) -> CachedUdpSocket {
        CachedUdpSocket {
            socket,
            applied_timeout: Cell::new(None),
            recv_buf: Mutex::new(vec![0u8; RECV_BUF_LEN]),
        }
    }

    fn apply_timeout(&self, timeout: StdDuration) -> Result<()> {
        if self.applied_timeout.get() != Some(timeout) {
            self.socket.set_read_timeout(Some(timeout))?;
            self.applied_timeout.set(Some(timeout));
        }
        Ok(())
    }

    fn is_timeout(e: &std::io::Error) -> bool {
        e.kind() == std::io::ErrorKind::WouldBlock || e.kind() == std::io::ErrorKind::TimedOut
    }

    /// `recv` on a connected socket.
    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>> {
        self.apply_timeout(timeout)?;
        let mut buf = self.recv_buf.lock().expect("transport mutex poisoned");
        match self.socket.recv(&mut buf) {
            Ok(n) => Ok(Some(buf[..n].to_vec())),
            Err(e) if Self::is_timeout(&e) => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    /// `recv_from` on an unconnected (daemon) socket.
    fn recv_from(&self, timeout: StdDuration) -> Result<Option<(Vec<u8>, SocketAddr)>> {
        self.apply_timeout(timeout)?;
        let mut buf = self.recv_buf.lock().expect("transport mutex poisoned");
        match self.socket.recv_from(&mut buf) {
            Ok((n, addr)) => Ok(Some((buf[..n].to_vec(), addr))),
            Err(e) if Self::is_timeout(&e) => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

/// Blocking UDP transport (client side; the scheduler daemon is a
/// blocking `recv_from` loop too — see [`crate::daemon`] — so the whole
/// deployment is plain sockets, no async runtime).
pub struct UdpTransport {
    inner: CachedUdpSocket,
}

impl UdpTransport {
    /// Bind an ephemeral local port and connect to the scheduler address.
    pub fn connect(scheduler_addr: &str) -> Result<UdpTransport> {
        let socket = UdpSocket::bind("0.0.0.0:0")?;
        socket.connect(scheduler_addr)?;
        Ok(UdpTransport {
            inner: CachedUdpSocket::new(socket),
        })
    }

    /// Local address (tests).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.inner.socket.local_addr()?)
    }
}

impl Transport for UdpTransport {
    fn send(&self, buf: &[u8]) -> Result<()> {
        self.inner.socket.send(buf)?;
        Ok(())
    }

    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>> {
        self.inner.recv(timeout)
    }
}

/// Daemon-side UDP endpoint with the same timeout/buffer caching as
/// [`UdpTransport`].
pub struct UdpServerTransport {
    inner: CachedUdpSocket,
}

impl UdpServerTransport {
    /// Bind the daemon socket (e.g. `127.0.0.1:7700`, or port 0 in
    /// tests).
    pub fn bind(addr: &str) -> Result<UdpServerTransport> {
        Ok(UdpServerTransport {
            inner: CachedUdpSocket::new(UdpSocket::bind(addr)?),
        })
    }

    /// Bound address (useful with port 0 in tests).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.inner.socket.local_addr()?)
    }
}

impl ServerTransport for UdpServerTransport {
    fn recv_from(&self, timeout: StdDuration) -> Result<Option<(Vec<u8>, SocketAddr)>> {
        self.inner.recv_from(timeout)
    }

    fn send_to(&self, buf: &[u8], addr: SocketAddr) -> Result<()> {
        self.inner.socket.send_to(buf, addr)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// LossyNet: deterministic lossy in-process datagram fabric
// ---------------------------------------------------------------------

struct LossyState {
    /// Client → daemon datagrams (with the sending client's address).
    to_server: VecDeque<(Vec<u8>, SocketAddr)>,
    /// Daemon → client inboxes, one per registered endpoint.
    inboxes: HashMap<SocketAddr, VecDeque<Vec<u8>>>,
    /// Independent drop-decision streams per direction, so the upstream
    /// decision sequence does not depend on downstream traffic volume.
    rng_up: Rng,
    rng_down: Rng,
    drop_permille: u32,
    dropped_up: u64,
    dropped_down: u64,
    /// Partition switch: while set, EVERY datagram in both directions
    /// is dropped (and counted), modelling a network partition of the
    /// node this fabric fronts. Healing just clears the flag — queued
    /// pre-partition datagrams are unaffected.
    partitioned: bool,
}

impl LossyState {
    fn roll(rng: &mut Rng, permille: u32) -> bool {
        permille > 0 && rng.next_u64() % 1000 < permille as u64
    }
}

/// A deterministic lossy in-process "network" between hook clients and
/// the scheduler daemon: every datagram in either direction is dropped
/// with probability `drop_permille`/1000, decided by a seeded PRNG (one
/// independent stream per direction). With `drop_permille == 0` it is a
/// reliable fabric — the same test scenario can run lossless and lossy
/// and compare outcomes, which is how dropped-datagram recovery is
/// proven in-process (`tests/integration_udp.rs`).
///
/// The *decision sequence* per direction is fixed by the seed; which
/// message an unlucky decision lands on can vary with thread
/// interleaving, so tests assert interleaving-independent invariants
/// (eventual release of every launch, conservation of hold/release
/// counters, empty daemon maps after churn) rather than exact drop
/// positions.
pub struct LossyNet {
    state: Mutex<LossyState>,
    cv: Condvar,
}

impl LossyNet {
    /// Build a fabric with the given seed and drop rate (per mille).
    pub fn new(seed: u64, drop_permille: u32) -> Arc<LossyNet> {
        assert!(drop_permille < 1000, "a fabric dropping everything cannot converge");
        Arc::new(LossyNet {
            state: Mutex::new(LossyState {
                to_server: VecDeque::new(),
                inboxes: HashMap::new(),
                rng_up: Rng::new(seed ^ 0x5157_4550),
                rng_down: Rng::new(seed ^ 0x444F_574E),
                drop_permille,
                dropped_up: 0,
                dropped_down: 0,
                partitioned: false,
            }),
            cv: Condvar::new(),
        })
    }

    /// Register a client endpoint under a synthetic address.
    pub fn client_endpoint(self: &Arc<Self>, port: u16) -> LossyTransport {
        let addr: SocketAddr = format!("127.0.0.1:{port}").parse().expect("synthetic addr");
        let mut s = self.state.lock().expect("lossy net poisoned");
        s.inboxes.entry(addr).or_default();
        drop(s);
        LossyTransport {
            net: Arc::clone(self),
            addr,
        }
    }

    /// The daemon-side endpoint of this fabric.
    pub fn server_endpoint(self: &Arc<Self>) -> LossyServerTransport {
        LossyServerTransport {
            net: Arc::clone(self),
        }
    }

    /// Datagrams dropped so far as `(client→daemon, daemon→client)`.
    pub fn dropped(&self) -> (u64, u64) {
        let s = self.state.lock().expect("lossy net poisoned");
        (s.dropped_up, s.dropped_down)
    }

    /// Partition (or heal) this fabric: while partitioned, every
    /// datagram in both directions vanishes. The node-failure churn
    /// scenario uses this to cut a node off mid-traffic and later heal
    /// it (`cluster::sim::run_node_churn`).
    pub fn set_partitioned(&self, partitioned: bool) {
        let mut s = self.state.lock().expect("lossy net poisoned");
        s.partitioned = partitioned;
        drop(s);
        // Wake blocked receivers so they re-check their deadlines.
        self.cv.notify_all();
    }
}

/// Client endpoint on a [`LossyNet`].
pub struct LossyTransport {
    net: Arc<LossyNet>,
    addr: SocketAddr,
}

impl LossyTransport {
    /// The synthetic address the daemon sees for this endpoint.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Transport for LossyTransport {
    fn send(&self, buf: &[u8]) -> Result<()> {
        let mut s = self.net.state.lock().expect("lossy net poisoned");
        let permille = s.drop_permille;
        if s.partitioned || LossyState::roll(&mut s.rng_up, permille) {
            s.dropped_up += 1;
            return Ok(()); // the datagram silently vanishes, as UDP would
        }
        s.to_server.push_back((buf.to_vec(), self.addr));
        drop(s);
        self.net.cv.notify_all();
        Ok(())
    }

    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>> {
        let deadline = Instant::now() + timeout;
        let mut s = self.net.state.lock().expect("lossy net poisoned");
        loop {
            if let Some(buf) = s
                .inboxes
                .get_mut(&self.addr)
                .and_then(VecDeque::pop_front)
            {
                return Ok(Some(buf));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (next, _) = self
                .net
                .cv
                .wait_timeout(s, deadline - now)
                .expect("lossy net poisoned");
            s = next;
        }
    }
}

/// Daemon endpoint on a [`LossyNet`].
pub struct LossyServerTransport {
    net: Arc<LossyNet>,
}

impl ServerTransport for LossyServerTransport {
    fn recv_from(&self, timeout: StdDuration) -> Result<Option<(Vec<u8>, SocketAddr)>> {
        let deadline = Instant::now() + timeout;
        let mut s = self.net.state.lock().expect("lossy net poisoned");
        loop {
            if let Some(item) = s.to_server.pop_front() {
                return Ok(Some(item));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (next, _) = self
                .net
                .cv
                .wait_timeout(s, deadline - now)
                .expect("lossy net poisoned");
            s = next;
        }
    }

    fn send_to(&self, buf: &[u8], addr: SocketAddr) -> Result<()> {
        let mut s = self.net.state.lock().expect("lossy net poisoned");
        let permille = s.drop_permille;
        if s.partitioned || LossyState::roll(&mut s.rng_down, permille) {
            s.dropped_down += 1;
            return Ok(());
        }
        if let Some(inbox) = s.inboxes.get_mut(&addr) {
            inbox.push_back(buf.to_vec());
        }
        // Unknown address → the void, exactly like UDP.
        drop(s);
        self.net.cv.notify_all();
        Ok(())
    }
}

/// A [`Transport`] wrapper with an external on/off switch: while the
/// gate is closed, sends vanish and receives time out, exactly as if
/// the link were cut. The node-failure churn scenario closes the gates
/// on a partitioned node's *outgoing* peer links (its inbound fabric is
/// cut with [`LossyNet::set_partitioned`]) so a partition severs the
/// node in both directions, then reopens them to heal.
pub struct GatedTransport<T: Transport> {
    inner: T,
    open: Arc<std::sync::atomic::AtomicBool>,
}

impl<T: Transport> GatedTransport<T> {
    /// Wrap `inner`; returns the transport and its gate (true = open).
    pub fn new(inner: T) -> (GatedTransport<T>, Arc<std::sync::atomic::AtomicBool>) {
        let open = Arc::new(std::sync::atomic::AtomicBool::new(true));
        (
            GatedTransport {
                inner,
                open: Arc::clone(&open),
            },
            open,
        )
    }

    fn is_open(&self) -> bool {
        self.open.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<T: Transport> Transport for GatedTransport<T> {
    fn send(&self, buf: &[u8]) -> Result<()> {
        if !self.is_open() {
            return Ok(()); // severed link: datagram vanishes silently
        }
        self.inner.send(buf)
    }

    fn recv(&self, timeout: StdDuration) -> Result<Option<Vec<u8>>> {
        if !self.is_open() {
            std::thread::sleep(timeout.min(StdDuration::from_millis(20)));
            return Ok(None);
        }
        self.inner.recv(timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_pair_round_trip() {
        let (client, server) = ChannelTransport::pair();
        client.send(b"hello").unwrap();
        let got = server.recv(StdDuration::from_millis(100)).unwrap().unwrap();
        assert_eq!(got, b"hello");
        server.send(b"world").unwrap();
        let got = client.recv(StdDuration::from_millis(100)).unwrap().unwrap();
        assert_eq!(got, b"world");
    }

    #[test]
    fn channel_recv_times_out() {
        let (client, _server) = ChannelTransport::pair();
        let got = client.recv(StdDuration::from_millis(10)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn lossless_net_delivers_in_order_both_ways() {
        let net = LossyNet::new(1, 0);
        let client = net.client_endpoint(9001);
        let server = net.server_endpoint();
        client.send(b"a").unwrap();
        client.send(b"b").unwrap();
        let (m1, from) = server.recv_from(StdDuration::from_millis(100)).unwrap().unwrap();
        let (m2, _) = server.recv_from(StdDuration::from_millis(100)).unwrap().unwrap();
        assert_eq!((m1.as_slice(), m2.as_slice()), (&b"a"[..], &b"b"[..]));
        assert_eq!(from, client.addr());
        server.send_to(b"c", from).unwrap();
        assert_eq!(
            client.recv(StdDuration::from_millis(100)).unwrap().unwrap(),
            b"c"
        );
        assert_eq!(net.dropped(), (0, 0));
        // Timeouts surface as None, not errors.
        assert!(client.recv(StdDuration::from_millis(5)).unwrap().is_none());
        assert!(server.recv_from(StdDuration::from_millis(5)).unwrap().is_none());
    }

    #[test]
    fn lossy_net_drops_are_seeded_and_counted() {
        let send_n = |seed: u64| -> (u64, u64) {
            let net = LossyNet::new(seed, 500);
            let client = net.client_endpoint(9001);
            let server = net.server_endpoint();
            for _ in 0..200 {
                client.send(b"x").unwrap();
                server.send_to(b"y", client.addr()).unwrap();
            }
            net.dropped()
        };
        let (up, down) = send_n(42);
        // ~50% drop rate on 200 datagrams per direction.
        assert!((50..150).contains(&up), "up drops way off: {up}");
        assert!((50..150).contains(&down), "down drops way off: {down}");
        // Deterministic per seed, different across seeds.
        assert_eq!(send_n(42), (up, down));
        assert_ne!(send_n(43), (up, down));
    }

    #[test]
    fn lossy_net_wakes_blocked_receiver() {
        let net = LossyNet::new(7, 0);
        let client = net.client_endpoint(9001);
        let server = net.server_endpoint();
        let h = std::thread::spawn(move || {
            server.recv_from(StdDuration::from_secs(2)).unwrap().unwrap()
        });
        std::thread::sleep(StdDuration::from_millis(20));
        client.send(b"wake").unwrap();
        let (buf, _) = h.join().unwrap();
        assert_eq!(buf, b"wake");
    }

    #[test]
    fn partition_cuts_both_directions_and_heals() {
        let net = LossyNet::new(3, 0);
        let client = net.client_endpoint(9001);
        let server = net.server_endpoint();
        net.set_partitioned(true);
        client.send(b"lost-up").unwrap();
        server.send_to(b"lost-down", client.addr()).unwrap();
        assert!(server.recv_from(StdDuration::from_millis(10)).unwrap().is_none());
        assert!(client.recv(StdDuration::from_millis(10)).unwrap().is_none());
        assert_eq!(net.dropped(), (1, 1));
        // Heal: traffic flows again, no residue from the partition.
        net.set_partitioned(false);
        client.send(b"up").unwrap();
        let (buf, from) = server.recv_from(StdDuration::from_millis(100)).unwrap().unwrap();
        assert_eq!(buf, b"up");
        server.send_to(b"down", from).unwrap();
        assert_eq!(
            client.recv(StdDuration::from_millis(100)).unwrap().unwrap(),
            b"down"
        );
    }

    #[test]
    fn gated_transport_severs_and_reopens() {
        let (a, b) = ChannelTransport::pair();
        let (gated, gate) = GatedTransport::new(a);
        gated.send(b"one").unwrap();
        assert_eq!(b.recv(StdDuration::from_millis(50)).unwrap().unwrap(), b"one");
        gate.store(false, std::sync::atomic::Ordering::Relaxed);
        gated.send(b"two").unwrap(); // vanishes
        b.send(b"three").unwrap(); // undeliverable while closed
        assert!(gated.recv(StdDuration::from_millis(10)).unwrap().is_none());
        gate.store(true, std::sync::atomic::Ordering::Relaxed);
        // The queued datagram from the peer is visible again (the gate
        // models a severed *link*, not a flushed queue)…
        assert_eq!(
            gated.recv(StdDuration::from_millis(50)).unwrap().unwrap(),
            b"three"
        );
        // …and the dropped send is gone for good.
        assert!(b.recv(StdDuration::from_millis(10)).unwrap().is_none());
    }

    #[test]
    fn udp_loopback_round_trip() {
        let server = UdpSocket::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let client = UdpTransport::connect(&addr.to_string()).unwrap();

        client.send(b"ping").unwrap();
        let mut buf = [0u8; 64];
        let (n, from) = server.recv_from(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");

        server.send_to(b"pong", from).unwrap();
        let got = client.recv(StdDuration::from_millis(200)).unwrap().unwrap();
        assert_eq!(got, b"pong");
    }
}
