//! The hook layer: the CUDA-API-hook analogue.
//!
//! In the paper, a preload library intercepts every `cudaLaunchKernel` of
//! a hosted service, resolves the kernel id against the `-rdynamic`
//! framework build, and talks to the central FIKIT scheduler over UDP;
//! the scheduler replies with launch-now / hold decisions.
//!
//! Here the same split exists:
//!
//! * [`protocol`] — the versioned wire format (client↔scheduler
//!   messages; serde-JSON frames over datagrams).
//! * [`client`] — the per-service hook client: intercept → resolve →
//!   forward → hold/launch.
//! * [`transport`] — pluggable datagram transports: an in-process
//!   channel pair (used by deterministic simulations and tests) and real
//!   UDP sockets (used by `fikit serve`, see [`crate::server`]).

pub mod client;
pub mod protocol;
pub mod transport;

pub use client::HookClient;
pub use protocol::{ClientMsg, SchedulerMsg, WIRE_VERSION};
pub use transport::{ChannelTransport, Transport, UdpTransport};
