//! The hook layer: the CUDA-API-hook analogue.
//!
//! In the paper, a preload library intercepts every `cudaLaunchKernel` of
//! a hosted service, resolves the kernel id against the `-rdynamic`
//! framework build, and talks to the central FIKIT scheduler over UDP;
//! the scheduler replies with launch-now / hold decisions.
//!
//! Here the same split exists:
//!
//! * [`protocol`] — the versioned wire format (client↔scheduler
//!   messages; JSON frames over datagrams) with the v2 loss-tolerant
//!   retransmit envelope (`msg_seq`, `Ack`, `ReleaseQuery`) and the v3
//!   federation control plane (`Redirect`/`RetryAfter` admission
//!   answers, node-to-node [`PeerMsg::Beacon`] gossip).
//! * [`client`] — the per-service hook client: intercept → resolve →
//!   forward → hold/launch, with exponential-backoff byte-identical
//!   retransmit, redirect following and multi-endpoint failover.
//! * [`transport`] — pluggable datagram transports: an in-process
//!   channel pair (deterministic simulations and tests), real UDP
//!   sockets (used by `fikit serve`, see [`crate::server`]), and the
//!   seeded lossy in-process fabric ([`LossyNet`]) that proves
//!   dropped-datagram recovery (DESIGN.md §Daemon).

pub mod client;
pub mod protocol;
pub mod transport;

pub use client::HookClient;
pub use protocol::{ClientMsg, PeerMsg, SchedulerMsg, KIND_PEER, WIRE_VERSION};
pub use transport::{
    ChannelTransport, GatedTransport, LossyNet, LossyServerTransport, LossyTransport,
    ServerTransport, Transport, UdpServerTransport, UdpTransport,
};
