//! The per-service hook client.
//!
//! In the paper this is the LD_PRELOADed library inside each service
//! container: it intercepts every kernel launch, resolves the kernel id
//! via the `-rdynamic` framework symbols, forwards the launch to the
//! FIKIT scheduler, and releases it to the GPU only when told to. Here it
//! fronts a [`Transport`] and is used by the real-time serving engine
//! (`runtime::engine`) and the UDP daemon integration tests.
//!
//! ## Loss tolerance (DESIGN.md §Daemon)
//!
//! The client assumes datagrams can vanish in either direction:
//!
//! * every message carries a monotonic `msg_seq`; a request is
//!   retransmitted **byte-identically** (same `msg_seq`) up to
//!   [`HookClient::set_retry`] attempts until its expected reply (or an
//!   [`SchedulerMsg::Ack`]) arrives — the daemon deduplicates on
//!   `msg_seq`, so retries never double-apply side effects;
//! * out-of-band `LaunchNow` releases observed while waiting for some
//!   other reply are buffered, so a release can never be lost between
//!   two client states;
//! * [`HookClient::wait_release`] polls with
//!   [`ClientMsg::ReleaseQuery`] when the wait times out, recovering
//!   releases whose datagram was dropped.
//!
//! The same retransmit discipline makes a *daemon restart* transparent
//! when the daemon runs with a session journal (ADR-004, `fikit serve
//! --journal DIR`): replay rebuilds the per-client dedup cache
//! (`last_msg_seq` + cached replies), so a request retransmitted across
//! the restart is answered from the cache exactly as a same-incarnation
//! duplicate would be, and a mutation lost to a torn final journal
//! record is simply re-applied when the retransmit arrives. The client
//! needs no reconnect logic and cannot tell the restart happened.

use super::protocol::{ClientMsg, SchedulerMsg};
use super::transport::Transport;
use crate::core::{Dim3, Error, KernelId, Priority, Result, SimTime, TaskId, TaskKey};
use crate::profile::SymbolResolver;
use std::collections::HashSet;
use std::time::{Duration as StdDuration, Instant};

/// Decision returned by the scheduler for one held launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchDecision {
    /// Launch to the GPU immediately.
    LaunchNow,
    /// Parked in a priority queue; a later `LaunchNow` will release it.
    Held,
}

/// Hook client state for one service process.
pub struct HookClient<T: Transport> {
    transport: T,
    task_key: TaskKey,
    priority: Priority,
    resolver: SymbolResolver,
    /// Model name hint forwarded in `Register` for placement scoring.
    model_hint: Option<String>,
    /// Scheduler-assigned stage from registration.
    sharing_stage: Option<bool>,
    /// Per-attempt reply wait.
    recv_timeout: StdDuration,
    /// Bounded retransmit attempts per request.
    max_attempts: u32,
    /// Monotonic wire sequence (starts at 1; 0 means "never sent").
    next_msg_seq: u64,
    /// Kernel seqs whose `LaunchNow` arrived out of band.
    released: HashSet<u32>,
}

impl<T: Transport> HookClient<T> {
    pub fn new(
        transport: T,
        task_key: TaskKey,
        priority: Priority,
        resolver: SymbolResolver,
    ) -> HookClient<T> {
        HookClient {
            transport,
            task_key,
            priority,
            resolver,
            model_hint: None,
            sharing_stage: None,
            recv_timeout: StdDuration::from_millis(500),
            max_attempts: 5,
            next_msg_seq: 1,
            released: HashSet::new(),
        }
    }

    pub fn task_key(&self) -> &TaskKey {
        &self.task_key
    }

    /// Forward a model name in `Register` so the daemon's registry can
    /// score shard placement with the compatibility matrix.
    pub fn with_model(mut self, model: &str) -> Self {
        self.model_hint = Some(model.to_string());
        self
    }

    /// Tune the bounded-retry loop: per-attempt reply wait and number of
    /// attempts. Lossy links want more attempts; in-process tests want
    /// shorter waits.
    pub fn set_retry(&mut self, recv_timeout: StdDuration, max_attempts: u32) {
        self.recv_timeout = recv_timeout;
        self.max_attempts = max_attempts.max(1);
    }

    /// Register with the scheduler; returns `true` if the service enters
    /// sharing stage (has a ready profile), `false` for measurement
    /// stage.
    pub fn register(&mut self) -> Result<bool> {
        let msg = ClientMsg::Register {
            task_key: self.task_key.clone(),
            priority: self.priority,
            has_symbols: self.resolver.model().symbols_exported,
            model: self.model_hint.clone(),
        };
        match self.request(&msg)? {
            SchedulerMsg::Registered { sharing_stage, .. } => {
                self.sharing_stage = Some(sharing_stage);
                Ok(sharing_stage)
            }
            other => Err(Error::Protocol(format!("unexpected reply: {other:?}"))),
        }
    }

    /// Announce a new task (invocation). Blocks until acknowledged.
    pub fn task_start(&mut self, task_id: TaskId) -> Result<()> {
        let msg = ClientMsg::TaskStart {
            task_key: self.task_key.clone(),
            task_id,
        };
        self.request(&msg).map(|_| ())
    }

    /// Intercept one kernel launch: resolve the kernel id, forward it,
    /// and return the scheduler's immediate decision.
    pub fn intercept_launch(
        &mut self,
        kernel: &KernelId,
        task_id: TaskId,
        seq: u32,
        now: SimTime,
    ) -> Result<LaunchDecision> {
        let (resolved, _cost) = self.resolver.resolve(kernel);
        let msg = ClientMsg::Launch {
            task_key: self.task_key.clone(),
            task_id,
            kernel_name: resolved.name.to_string(),
            grid: resolved.grid,
            block: resolved.block,
            seq,
            issued_at: now,
        };
        match self.request(&msg)? {
            SchedulerMsg::LaunchNow { .. } => Ok(LaunchDecision::LaunchNow),
            SchedulerMsg::Hold { .. } => Ok(LaunchDecision::Held),
            other => Err(Error::Protocol(format!("unexpected reply: {other:?}"))),
        }
    }

    /// Wait for a deferred `LaunchNow` for a held kernel. When the wait
    /// times out, polls the daemon with `ReleaseQuery` — the release
    /// datagram itself may have been dropped.
    pub fn wait_release(&mut self, seq: u32) -> Result<()> {
        if self.released.remove(&seq) {
            return Ok(());
        }
        for _ in 0..self.max_attempts {
            let deadline = Instant::now() + self.recv_timeout;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.transport.recv(deadline - now)? {
                    Some(buf) => match SchedulerMsg::decode(&buf)? {
                        SchedulerMsg::LaunchNow { seq: s, .. } if s == seq => return Ok(()),
                        other => self.absorb(&other),
                    },
                    None => break,
                }
            }
            // Timed out: the release may have been dropped — poll.
            let query = ClientMsg::ReleaseQuery {
                task_key: self.task_key.clone(),
                seq,
            };
            match self.request(&query)? {
                SchedulerMsg::LaunchNow { seq: s, .. } if s == seq => return Ok(()),
                SchedulerMsg::Hold { .. } => continue, // still parked
                other => {
                    return Err(Error::Protocol(format!(
                        "release query for seq {seq} answered {other:?}"
                    )))
                }
            }
        }
        Err(Error::Protocol(format!(
            "launch seq {seq} was never released"
        )))
    }

    /// Report a kernel completion (measurement stage / holder kernels).
    /// Blocks until acknowledged — a lost completion would silently cost
    /// a fill window.
    pub fn report_completion(
        &mut self,
        task_id: TaskId,
        seq: u32,
        exec: crate::core::Duration,
        finished_at: SimTime,
    ) -> Result<()> {
        let msg = ClientMsg::Completion {
            task_key: self.task_key.clone(),
            task_id,
            seq,
            exec,
            finished_at,
        };
        self.request(&msg).map(|_| ())
    }

    /// Announce the current task finished. Blocks until acknowledged.
    pub fn task_end(&mut self, task_id: TaskId) -> Result<()> {
        let msg = ClientMsg::TaskEnd {
            task_key: self.task_key.clone(),
            task_id,
        };
        let r = self.request(&msg).map(|_| ());
        // Seqs may be reused by the next task; drop stale buffered
        // releases (the daemon clears its released record too).
        self.released.clear();
        r
    }

    /// Clean shutdown. Blocks until acknowledged (the daemon treats
    /// `Disconnect` for an unknown service as already-done and acks it,
    /// so retransmits converge).
    pub fn disconnect(&mut self) -> Result<()> {
        let msg = ClientMsg::Disconnect {
            task_key: self.task_key.clone(),
        };
        self.request(&msg).map(|_| ())
    }

    /// Send `msg` with a fresh `msg_seq` and retransmit byte-identically
    /// until a reply *for this request* arrives. Out-of-band traffic
    /// (deferred releases, stale acks) is absorbed, never dropped.
    fn request(&mut self, msg: &ClientMsg) -> Result<SchedulerMsg> {
        let msg_seq = self.next_msg_seq;
        self.next_msg_seq += 1;
        let bytes = msg.encode_seq(msg_seq)?;
        for _ in 0..self.max_attempts {
            self.transport.send(&bytes)?;
            let deadline = Instant::now() + self.recv_timeout;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break; // attempt timed out → retransmit
                }
                let Some(buf) = self.transport.recv(deadline - now)? else {
                    break;
                };
                let reply = SchedulerMsg::decode(&buf)?;
                if Self::matches(msg, msg_seq, &reply) {
                    return Ok(reply);
                }
                if let SchedulerMsg::Error { message } = &reply {
                    return Err(Error::Protocol(message.clone()));
                }
                self.absorb(&reply);
            }
        }
        Err(Error::Protocol(format!(
            "no reply after {} attempts (msg_seq {msg_seq})",
            self.max_attempts
        )))
    }

    /// Is `reply` the direct answer to `msg`?
    fn matches(msg: &ClientMsg, msg_seq: u64, reply: &SchedulerMsg) -> bool {
        match (msg, reply) {
            (ClientMsg::Register { .. }, SchedulerMsg::Registered { .. }) => true,
            (
                ClientMsg::Launch { seq, .. },
                SchedulerMsg::LaunchNow { seq: s, .. } | SchedulerMsg::Hold { seq: s, .. },
            )
            | (
                ClientMsg::ReleaseQuery { seq, .. },
                SchedulerMsg::LaunchNow { seq: s, .. } | SchedulerMsg::Hold { seq: s, .. },
            ) => s == seq,
            (_, SchedulerMsg::Ack { msg_seq: acked }) => *acked == msg_seq,
            _ => false,
        }
    }

    /// Bank out-of-band messages that matter later; ignore the rest.
    fn absorb(&mut self, reply: &SchedulerMsg) {
        if let SchedulerMsg::LaunchNow { seq, .. } = reply {
            self.released.insert(*seq);
        }
    }

    /// Erase a kernel id through the client's resolver (test helper).
    pub fn resolve(&self, kernel: &KernelId) -> KernelId {
        self.resolver.resolve(kernel).0
    }
}

/// Convenience constructor for an in-proc client/server pair used by
/// tests and the real-time engine.
pub fn in_proc_pair(
    task_key: TaskKey,
    priority: Priority,
    resolver: SymbolResolver,
) -> (HookClient<super::transport::ChannelTransport>, super::transport::ChannelTransport) {
    let (client_t, server_t) = super::transport::ChannelTransport::pair();
    (
        HookClient::new(client_t, task_key, priority, resolver),
        server_t,
    )
}

/// Build a [`KernelId`] from the wire fields of a `Launch` message.
pub fn kernel_id_from_wire(kernel_name: &str, grid: Dim3, block: Dim3) -> KernelId {
    KernelId::new(kernel_name.to_string(), grid, block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hook::protocol::ClientMsg;
    use crate::hook::transport::Transport;
    use crate::profile::SymbolTableModel;

    fn pair() -> (
        HookClient<crate::hook::ChannelTransport>,
        crate::hook::ChannelTransport,
    ) {
        in_proc_pair(
            TaskKey::new("svc"),
            Priority::P1,
            SymbolResolver::new(SymbolTableModel::default()),
        )
    }

    #[test]
    fn register_round_trip() {
        let (mut client, server) = pair();
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let msg = ClientMsg::decode(&buf).unwrap();
            let ClientMsg::Register { task_key, priority, has_symbols, .. } = msg else {
                panic!("expected Register, got {msg:?}");
            };
            assert_eq!(priority, Priority::P1);
            assert!(has_symbols);
            let reply = SchedulerMsg::Registered {
                task_key,
                sharing_stage: true,
            };
            server.send(&reply.encode().unwrap()).unwrap();
        });
        assert!(client.register().unwrap());
        h.join().unwrap();
    }

    #[test]
    fn launch_decision_round_trip() {
        let (mut client, server) = pair();
        let kernel = KernelId::new("gemm", Dim3::x(8), Dim3::x(128));
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let ClientMsg::Launch { task_key, task_id, seq, kernel_name, .. } =
                ClientMsg::decode(&buf).unwrap()
            else {
                panic!("expected Launch");
            };
            assert_eq!(kernel_name, "gemm");
            let reply = SchedulerMsg::Hold { task_key: task_key.clone(), task_id, seq };
            server.send(&reply.encode().unwrap()).unwrap();
            // Later, release it.
            let release = SchedulerMsg::LaunchNow { task_key, task_id, seq };
            server.send(&release.encode().unwrap()).unwrap();
        });
        let decision = client
            .intercept_launch(&kernel, TaskId(3), 7, SimTime::ZERO)
            .unwrap();
        assert_eq!(decision, LaunchDecision::Held);
        client.wait_release(7).unwrap();
        h.join().unwrap();
    }

    /// A dropped reply triggers a byte-identical retransmit; the first
    /// answered attempt wins.
    #[test]
    fn register_retransmits_until_answered() {
        let (mut client, server) = pair();
        client.set_retry(StdDuration::from_millis(30), 5);
        let h = std::thread::spawn(move || {
            // "Drop" the first datagram by ignoring it.
            let first = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let second = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            assert_eq!(first, second, "retransmit must be byte-identical");
            let ClientMsg::Register { task_key, .. } = ClientMsg::decode(&second).unwrap() else {
                panic!("expected Register");
            };
            let reply = SchedulerMsg::Registered {
                task_key,
                sharing_stage: false,
            };
            server.send(&reply.encode().unwrap()).unwrap();
        });
        assert!(!client.register().unwrap());
        h.join().unwrap();
    }

    /// Lifecycle messages block for the matching Ack, skipping stale
    /// out-of-band traffic; buffered releases satisfy a later
    /// wait_release without touching the wire.
    #[test]
    fn ack_matching_and_release_buffering() {
        let (mut client, server) = pair();
        client.set_retry(StdDuration::from_millis(200), 3);
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let (msg_seq, msg) = ClientMsg::decode_seq(&buf).unwrap();
            assert!(matches!(msg, ClientMsg::TaskStart { .. }));
            // Interleave an out-of-band release and a stale ack before
            // the real ack.
            let release = SchedulerMsg::LaunchNow {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(0),
                seq: 9,
            };
            server.send(&release.encode().unwrap()).unwrap();
            server
                .send(&SchedulerMsg::Ack { msg_seq: msg_seq + 100 }.encode().unwrap())
                .unwrap();
            server
                .send(&SchedulerMsg::Ack { msg_seq }.encode().unwrap())
                .unwrap();
        });
        client.task_start(TaskId(0)).unwrap();
        h.join().unwrap();
        // The banked release resolves instantly — no server needed.
        client.set_retry(StdDuration::from_millis(10), 1);
        client.wait_release(9).unwrap();
    }

    #[test]
    fn timeout_is_an_error() {
        let (mut client, _server) = pair();
        client.set_retry(StdDuration::from_millis(5), 2);
        assert!(client.register().is_err());
    }
}
