//! The per-service hook client.
//!
//! In the paper this is the LD_PRELOADed library inside each service
//! container: it intercepts every kernel launch, resolves the kernel id
//! via the `-rdynamic` framework symbols, forwards the launch to the
//! FIKIT scheduler, and releases it to the GPU only when told to. Here it
//! fronts a [`Transport`] and is used by the real-time serving engine
//! (`runtime::engine`) and the UDP daemon integration tests.
//!
//! ## Loss tolerance (DESIGN.md §Daemon)
//!
//! The client assumes datagrams can vanish in either direction:
//!
//! * every message carries a monotonic `msg_seq`; a request is
//!   retransmitted **byte-identically** (same `msg_seq`) until its
//!   expected reply (or an [`SchedulerMsg::Ack`]) arrives — the daemon
//!   deduplicates on `msg_seq`, so retries never double-apply side
//!   effects. Retransmit pacing is exponential backoff with
//!   deterministic jitter: 10 ms initial, doubling to the
//!   [`HookClient::set_retry`] cap (500 ms default), jittered by a
//!   per-client seeded PRNG so a fleet of clients retrying into the
//!   same daemon spreads out instead of thundering in lockstep;
//! * out-of-band `LaunchNow` releases observed while waiting for some
//!   other reply are buffered, so a release can never be lost between
//!   two client states;
//! * [`HookClient::wait_release`] polls with
//!   [`ClientMsg::ReleaseQuery`] when the wait times out, recovering
//!   releases whose datagram was dropped — bounded by an overall
//!   deadline ([`HookClient::set_release_deadline`]) so it can never
//!   spin forever against a dead node.
//!
//! ## Failover (DESIGN.md §Fleet-federation)
//!
//! With [`HookClient::add_endpoint`] the client knows several fleet
//! nodes. Two control-plane paths move it between them:
//!
//! * **Redirect** — a full node answers `Register` with
//!   `Redirect{node}`; the client switches to that endpoint and
//!   re-registers there. `RetryAfter{ms, reason}` (the whole visible
//!   fleet is full) surfaces as [`Error::Shed`] — an explicit,
//!   reasoned rejection, never a silent timeout.
//! * **Failover** — when the current node stops answering entirely
//!   (every backoff attempt exhausted), the client advances to the
//!   next endpoint and transparently re-establishes its session there:
//!   fresh `Register`, re-announced open task, and re-issued held
//!   launches whose `ReleaseQuery` the new node cannot answer. Fresh
//!   `msg_seq` allocation makes this safe: the new node sees an
//!   ordinary new session, and the dead node's dedup state is
//!   irrelevant.
//!
//! The same retransmit discipline makes a *daemon restart* transparent
//! when the daemon runs with a session journal (ADR-004, `fikit serve
//! --journal DIR`): replay rebuilds the per-client dedup cache
//! (`last_msg_seq` + cached replies), so a request retransmitted across
//! the restart is answered from the cache exactly as a same-incarnation
//! duplicate would be, and a mutation lost to a torn final journal
//! record is simply re-applied when the retransmit arrives.

use super::protocol::{ClientMsg, SchedulerMsg};
use super::transport::Transport;
use crate::core::{Dim3, Error, KernelId, Priority, Result, SimTime, TaskId, TaskKey};
use crate::profile::SymbolResolver;
use crate::util::rng::Rng;
use std::collections::{HashMap, HashSet};
use std::time::{Duration as StdDuration, Instant};

/// Decision returned by the scheduler for one held launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchDecision {
    /// Launch to the GPU immediately.
    LaunchNow,
    /// Parked in a priority queue; a later `LaunchNow` will release it.
    Held,
}

/// First retransmit wait; doubles per attempt up to the `set_retry`
/// cap (lazy start: don't hammer a daemon that answers within 10 ms).
const BACKOFF_BASE: StdDuration = StdDuration::from_millis(10);

/// Outcome of one session re-establishment attempt after failover.
enum Reestablish {
    /// Session is live on the (possibly redirected-to) current endpoint.
    Done,
    /// The failover target did not answer either — advance again.
    Dead,
}

/// Hook client state for one service process.
pub struct HookClient<T: Transport> {
    /// Known fleet endpoints as `(node name, transport)`; redirects
    /// switch between them by name, failover round-robins.
    endpoints: Vec<(String, T)>,
    current: usize,
    task_key: TaskKey,
    priority: Priority,
    resolver: SymbolResolver,
    /// Model name hint forwarded in `Register` for placement scoring.
    model_hint: Option<String>,
    /// Scheduler-assigned stage from registration.
    sharing_stage: Option<bool>,
    /// Backoff cap: no single reply wait exceeds this.
    recv_timeout: StdDuration,
    /// Bounded retransmit attempts per request (per endpoint).
    max_attempts: u32,
    /// Overall bound on one `wait_release` call, across every recv
    /// phase and `ReleaseQuery` poll it makes.
    release_deadline: StdDuration,
    /// Deterministic backoff jitter, seeded from the task key.
    jitter: Rng,
    /// Monotonic wire sequence (starts at 1; 0 means "never sent").
    /// Spans endpoints — a failed-over session keeps counting up, so
    /// the new node just sees a client whose seqs start high.
    next_msg_seq: u64,
    /// Kernel seqs whose `LaunchNow` arrived out of band.
    released: HashSet<u32>,
    /// Held launches not yet released: the original `Launch` message
    /// plus the failover count when it was issued, so a post-failover
    /// node that never saw the launch can be handed it again (and a
    /// same-node "unknown seq" answer still surfaces as the error it
    /// always was).
    held: HashMap<u32, (ClientMsg, u64)>,
    /// Successfully registered at least once (failover re-registers).
    registered: bool,
    /// Task announced by `task_start` and not yet ended — re-announced
    /// on the failover target before anything else.
    open_task: Option<TaskId>,
    /// Endpoint switches forced by an unresponsive node.
    failovers: u64,
}

impl<T: Transport> HookClient<T> {
    pub fn new(
        transport: T,
        task_key: TaskKey,
        priority: Priority,
        resolver: SymbolResolver,
    ) -> HookClient<T> {
        // Deterministic per-client jitter stream: same client key ⇒
        // same backoff schedule, different keys ⇒ decorrelated retries.
        let mut seed = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
        for b in task_key.as_str().bytes() {
            seed ^= u64::from(b);
            seed = seed.wrapping_mul(0x100_0000_01b3);
        }
        HookClient {
            endpoints: vec![("primary".to_string(), transport)],
            current: 0,
            task_key,
            priority,
            resolver,
            model_hint: None,
            sharing_stage: None,
            recv_timeout: StdDuration::from_millis(500),
            max_attempts: 5,
            release_deadline: StdDuration::from_secs(60),
            jitter: Rng::new(seed),
            next_msg_seq: 1,
            released: HashSet::new(),
            held: HashMap::new(),
            registered: false,
            open_task: None,
            failovers: 0,
        }
    }

    pub fn task_key(&self) -> &TaskKey {
        &self.task_key
    }

    /// Forward a model name in `Register` so the daemon's registry can
    /// score shard placement with the compatibility matrix.
    pub fn with_model(mut self, model: &str) -> Self {
        self.model_hint = Some(model.to_string());
        self
    }

    /// Name the initial endpoint (default `"primary"`). Names must
    /// match the daemons' advertised node names for redirects to
    /// resolve.
    pub fn with_primary_name(mut self, name: &str) -> Self {
        self.endpoints[0].0 = name.to_string();
        self
    }

    /// Add a failover endpoint for the named fleet node. Order matters:
    /// failover round-robins in insertion order.
    pub fn add_endpoint(&mut self, node: &str, transport: T) {
        self.endpoints.push((node.to_string(), transport));
    }

    /// The node the client is currently talking to.
    pub fn current_endpoint(&self) -> &str {
        &self.endpoints[self.current].0
    }

    /// Endpoint switches forced by an unresponsive node so far.
    pub fn failovers(&self) -> u64 {
        self.failovers
    }

    /// Tune the bounded-retry loop: backoff cap (longest single reply
    /// wait) and number of attempts per endpoint. Lossy links want more
    /// attempts; in-process tests want shorter waits.
    pub fn set_retry(&mut self, recv_timeout: StdDuration, max_attempts: u32) {
        self.recv_timeout = recv_timeout;
        self.max_attempts = max_attempts.max(1);
    }

    /// Cap one `wait_release` call end to end (default 60 s): however
    /// the per-attempt arithmetic works out, the client will not poll a
    /// dead or wedged node past this.
    pub fn set_release_deadline(&mut self, deadline: StdDuration) {
        self.release_deadline = deadline;
    }

    /// Register with the scheduler; returns `true` if the service enters
    /// sharing stage (has a ready profile), `false` for measurement
    /// stage. A full fleet answers with [`Error::Shed`] (explicit,
    /// reasoned); a full *node* with live peers redirects transparently.
    pub fn register(&mut self) -> Result<bool> {
        let msg = self.register_msg();
        match self.request(&msg)? {
            SchedulerMsg::Registered { sharing_stage, .. } => {
                self.sharing_stage = Some(sharing_stage);
                self.registered = true;
                Ok(sharing_stage)
            }
            other => Err(Error::Protocol(format!("unexpected reply: {other:?}"))),
        }
    }

    fn register_msg(&self) -> ClientMsg {
        ClientMsg::Register {
            task_key: self.task_key.clone(),
            priority: self.priority,
            has_symbols: self.resolver.model().symbols_exported,
            model: self.model_hint.clone(),
        }
    }

    /// Announce a new task (invocation). Blocks until acknowledged.
    pub fn task_start(&mut self, task_id: TaskId) -> Result<()> {
        let msg = ClientMsg::TaskStart {
            task_key: self.task_key.clone(),
            task_id,
        };
        self.request(&msg)?;
        self.open_task = Some(task_id);
        Ok(())
    }

    /// Intercept one kernel launch: resolve the kernel id, forward it,
    /// and return the scheduler's immediate decision.
    pub fn intercept_launch(
        &mut self,
        kernel: &KernelId,
        task_id: TaskId,
        seq: u32,
        now: SimTime,
    ) -> Result<LaunchDecision> {
        let (resolved, _cost) = self.resolver.resolve(kernel);
        let msg = ClientMsg::Launch {
            task_key: self.task_key.clone(),
            task_id,
            kernel_name: resolved.name.to_string(),
            grid: resolved.grid,
            block: resolved.block,
            seq,
            issued_at: now,
        };
        match self.request(&msg)? {
            SchedulerMsg::LaunchNow { .. } => Ok(LaunchDecision::LaunchNow),
            SchedulerMsg::Hold { .. } => {
                // Remember the launch while it is parked: a failover
                // target that never saw it gets it re-issued.
                self.held.insert(seq, (msg, self.failovers));
                Ok(LaunchDecision::Held)
            }
            other => Err(Error::Protocol(format!("unexpected reply: {other:?}"))),
        }
    }

    /// Wait for a deferred `LaunchNow` for a held kernel. When the wait
    /// times out, polls the daemon with `ReleaseQuery` — the release
    /// datagram itself may have been dropped. Bounded twice over: by
    /// `max_attempts` poll rounds and by the overall release deadline.
    pub fn wait_release(&mut self, seq: u32) -> Result<()> {
        if self.released.remove(&seq) {
            self.held.remove(&seq);
            return Ok(());
        }
        let overall = Instant::now() + self.release_deadline;
        for _ in 0..self.max_attempts {
            let deadline = (Instant::now() + self.recv_timeout).min(overall);
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.endpoints[self.current].1.recv(deadline - now)? {
                    Some(buf) => match SchedulerMsg::decode(&buf)? {
                        SchedulerMsg::LaunchNow { seq: s, .. } if s == seq => {
                            self.held.remove(&seq);
                            return Ok(());
                        }
                        other => self.absorb(&other),
                    },
                    None => break,
                }
            }
            if Instant::now() >= overall {
                break; // overall deadline: stop polling, fail loudly
            }
            // Timed out: the release may have been dropped — poll.
            let query = ClientMsg::ReleaseQuery {
                task_key: self.task_key.clone(),
                seq,
            };
            match self.request(&query) {
                Ok(SchedulerMsg::LaunchNow { seq: s, .. }) if s == seq => {
                    self.held.remove(&seq);
                    return Ok(());
                }
                Ok(SchedulerMsg::Hold { .. }) => continue, // still parked
                Ok(other) => {
                    return Err(Error::Protocol(format!(
                        "release query for seq {seq} answered {other:?}"
                    )))
                }
                Err(Error::Protocol(m)) if m.contains("is unknown") => {
                    // The answering node has no record of this launch.
                    // If we failed over since it was held, the new node
                    // simply never saw it: re-issue it there (fresh
                    // msg_seq — an ordinary new launch to that node).
                    // On the SAME node this is the genuine purged/
                    // never-held error it always was.
                    let Some((launch, epoch)) = self.held.get(&seq).cloned() else {
                        return Err(Error::Protocol(m));
                    };
                    if epoch == self.failovers {
                        return Err(Error::Protocol(m));
                    }
                    self.held.insert(seq, (launch.clone(), self.failovers));
                    match self.request(&launch)? {
                        SchedulerMsg::LaunchNow { .. } => {
                            self.held.remove(&seq);
                            self.released.remove(&seq);
                            return Ok(());
                        }
                        SchedulerMsg::Hold { .. } => continue,
                        other => {
                            return Err(Error::Protocol(format!(
                                "re-issued launch seq {seq} answered {other:?}"
                            )))
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Err(Error::Protocol(format!(
            "launch seq {seq} was never released"
        )))
    }

    /// Report a kernel completion (measurement stage / holder kernels).
    /// Blocks until acknowledged — a lost completion would silently cost
    /// a fill window.
    pub fn report_completion(
        &mut self,
        task_id: TaskId,
        seq: u32,
        exec: crate::core::Duration,
        finished_at: SimTime,
    ) -> Result<()> {
        let msg = ClientMsg::Completion {
            task_key: self.task_key.clone(),
            task_id,
            seq,
            exec,
            finished_at,
        };
        self.request(&msg).map(|_| ())
    }

    /// Announce the current task finished. Blocks until acknowledged.
    pub fn task_end(&mut self, task_id: TaskId) -> Result<()> {
        let msg = ClientMsg::TaskEnd {
            task_key: self.task_key.clone(),
            task_id,
        };
        let r = self.request(&msg).map(|_| ());
        self.open_task = None;
        // Seqs may be reused by the next task; drop stale buffered
        // releases (the daemon clears its released record too).
        self.released.clear();
        self.held.clear();
        r
    }

    /// Clean shutdown. Blocks until acknowledged (the daemon treats
    /// `Disconnect` for an unknown service as already-done and acks it,
    /// so retransmits — and failover to a node that never saw us —
    /// converge).
    pub fn disconnect(&mut self) -> Result<()> {
        let msg = ClientMsg::Disconnect {
            task_key: self.task_key.clone(),
        };
        let r = self.request(&msg).map(|_| ());
        if r.is_ok() {
            self.registered = false;
        }
        r
    }

    /// Reply wait for retransmit attempt `attempt`: exponential from
    /// [`BACKOFF_BASE`] capped at `recv_timeout`, plus up to 25%
    /// deterministic jitter.
    fn backoff_wait(&mut self, attempt: u32) -> StdDuration {
        let base = BACKOFF_BASE.saturating_mul(1u32 << attempt.min(16));
        let wait = base.min(self.recv_timeout);
        let jitter_ns = self.jitter.below((wait.as_nanos() as u64 / 4).max(1));
        wait + StdDuration::from_nanos(jitter_ns)
    }

    /// One request against the CURRENT endpoint: allocate a `msg_seq`,
    /// send, and retransmit **byte-identically** on an exponential
    /// backoff schedule until a reply for this request arrives.
    /// `Ok(None)` means the endpoint never answered (dead or
    /// partitioned) — the caller decides whether to fail over.
    fn exchange(&mut self, msg: &ClientMsg) -> Result<Option<SchedulerMsg>> {
        let msg_seq = self.next_msg_seq;
        self.next_msg_seq += 1;
        let bytes = msg.encode_seq(msg_seq)?;
        for attempt in 0..self.max_attempts {
            self.endpoints[self.current].1.send(&bytes)?;
            let deadline = Instant::now() + self.backoff_wait(attempt);
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break; // attempt timed out → retransmit
                }
                let Some(buf) = self.endpoints[self.current].1.recv(deadline - now)? else {
                    break;
                };
                let reply = SchedulerMsg::decode(&buf)?;
                if Self::matches(msg, msg_seq, &reply) {
                    return Ok(Some(reply));
                }
                if let SchedulerMsg::Error { message } = &reply {
                    return Err(Error::Protocol(message.clone()));
                }
                self.absorb(&reply);
            }
        }
        Ok(None)
    }

    /// Send `msg`, following `Redirect`s, surfacing `RetryAfter` as
    /// [`Error::Shed`], and failing over to the next endpoint when the
    /// current one stops answering. Single-endpoint clients keep the
    /// old behaviour: endpoint death is a protocol error.
    fn request(&mut self, msg: &ClientMsg) -> Result<SchedulerMsg> {
        let mut redirects = 0usize;
        let mut deaths = 0usize;
        loop {
            match self.exchange(msg)? {
                Some(SchedulerMsg::Redirect { node, .. }) => {
                    redirects += 1;
                    if redirects > self.endpoints.len() {
                        return Err(Error::Shed(format!(
                            "redirect loop after {redirects} hops"
                        )));
                    }
                    self.switch_to(&node)?;
                }
                Some(SchedulerMsg::RetryAfter { ms, reason, .. }) => {
                    return Err(Error::Shed(format!("{reason} (retry after {ms} ms)")));
                }
                Some(reply) => return Ok(reply),
                None => loop {
                    deaths += 1;
                    if self.endpoints.len() < 2 || deaths >= self.endpoints.len() {
                        return Err(Error::Protocol(format!(
                            "no reply after {} attempts (endpoint {:?})",
                            self.max_attempts,
                            self.current_endpoint()
                        )));
                    }
                    self.current = (self.current + 1) % self.endpoints.len();
                    self.failovers += 1;
                    self.drain_endpoint();
                    // Register establishes its own session and Disconnect
                    // converges on an unknown node (acked as done);
                    // everything else needs the session rebuilt first.
                    let needs_session = self.registered
                        && !matches!(
                            msg,
                            ClientMsg::Register { .. } | ClientMsg::Disconnect { .. }
                        );
                    if !needs_session {
                        break;
                    }
                    match self.reestablish()? {
                        Reestablish::Done => break,
                        Reestablish::Dead => continue, // advance again
                    }
                },
            }
        }
    }

    /// Rebuild the session on the current endpoint after failover:
    /// `Register` (following redirects), then re-announce the open
    /// task. `Dead` = this endpoint does not answer either.
    fn reestablish(&mut self) -> Result<Reestablish> {
        let reg = self.register_msg();
        for _ in 0..=self.endpoints.len() {
            match self.exchange(&reg)? {
                Some(SchedulerMsg::Registered { sharing_stage, .. }) => {
                    self.sharing_stage = Some(sharing_stage);
                    if let Some(task_id) = self.open_task {
                        let ts = ClientMsg::TaskStart {
                            task_key: self.task_key.clone(),
                            task_id,
                        };
                        match self.exchange(&ts)? {
                            Some(SchedulerMsg::Ack { .. }) => {}
                            Some(other) => {
                                return Err(Error::Protocol(format!(
                                    "failover TaskStart answered {other:?}"
                                )))
                            }
                            None => return Ok(Reestablish::Dead),
                        }
                    }
                    return Ok(Reestablish::Done);
                }
                Some(SchedulerMsg::Redirect { node, .. }) => self.switch_to(&node)?,
                Some(SchedulerMsg::RetryAfter { ms, reason, .. }) => {
                    return Err(Error::Shed(format!("{reason} (retry after {ms} ms)")));
                }
                Some(other) => {
                    return Err(Error::Protocol(format!(
                        "failover Register answered {other:?}"
                    )))
                }
                None => return Ok(Reestablish::Dead),
            }
        }
        Err(Error::Shed(
            "redirect loop during failover re-registration".into(),
        ))
    }

    /// Switch to the endpoint for `node`. A redirect to a node this
    /// client has no endpoint for is handled as a shed: the daemon
    /// answered, the client just cannot follow.
    fn switch_to(&mut self, node: &str) -> Result<()> {
        match self.endpoints.iter().position(|(n, _)| n == node) {
            Some(i) => {
                self.current = i;
                self.drain_endpoint();
                Ok(())
            }
            None => Err(Error::Shed(format!(
                "redirected to {node:?}, but this client has no endpoint for it"
            ))),
        }
    }

    /// Absorb whatever is buffered on the endpoint we just switched to.
    /// An endpoint left behind earlier may hold stale replies (e.g. an
    /// `Error` a restarted node sent for our long-abandoned retransmit);
    /// reading them during a fresh exchange would poison it. Releases
    /// are still banked; everything else is stale by construction.
    fn drain_endpoint(&mut self) {
        for _ in 0..1024 {
            match self.endpoints[self.current].1.recv(StdDuration::from_millis(1)) {
                Ok(Some(buf)) => {
                    if let Ok(reply) = SchedulerMsg::decode(&buf) {
                        self.absorb(&reply);
                    }
                }
                _ => break,
            }
        }
    }

    /// Is `reply` the direct answer to `msg`?
    fn matches(msg: &ClientMsg, msg_seq: u64, reply: &SchedulerMsg) -> bool {
        match (msg, reply) {
            (ClientMsg::Register { .. }, SchedulerMsg::Registered { .. })
            | (
                ClientMsg::Register { .. },
                SchedulerMsg::Redirect { .. } | SchedulerMsg::RetryAfter { .. },
            ) => true,
            (
                ClientMsg::Launch { seq, .. },
                SchedulerMsg::LaunchNow { seq: s, .. } | SchedulerMsg::Hold { seq: s, .. },
            )
            | (
                ClientMsg::ReleaseQuery { seq, .. },
                SchedulerMsg::LaunchNow { seq: s, .. } | SchedulerMsg::Hold { seq: s, .. },
            ) => s == seq,
            (_, SchedulerMsg::Ack { msg_seq: acked }) => *acked == msg_seq,
            _ => false,
        }
    }

    /// Bank out-of-band messages that matter later; ignore the rest.
    fn absorb(&mut self, reply: &SchedulerMsg) {
        if let SchedulerMsg::LaunchNow { seq, .. } = reply {
            self.released.insert(*seq);
        }
    }

    /// Erase a kernel id through the client's resolver (test helper).
    pub fn resolve(&self, kernel: &KernelId) -> KernelId {
        self.resolver.resolve(kernel).0
    }
}

/// Convenience constructor for an in-proc client/server pair used by
/// tests and the real-time engine.
pub fn in_proc_pair(
    task_key: TaskKey,
    priority: Priority,
    resolver: SymbolResolver,
) -> (HookClient<super::transport::ChannelTransport>, super::transport::ChannelTransport) {
    let (client_t, server_t) = super::transport::ChannelTransport::pair();
    (
        HookClient::new(client_t, task_key, priority, resolver),
        server_t,
    )
}

/// Build a [`KernelId`] from the wire fields of a `Launch` message.
pub fn kernel_id_from_wire(kernel_name: &str, grid: Dim3, block: Dim3) -> KernelId {
    KernelId::new(kernel_name.to_string(), grid, block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hook::protocol::ClientMsg;
    use crate::hook::transport::Transport;
    use crate::profile::SymbolTableModel;

    fn pair() -> (
        HookClient<crate::hook::ChannelTransport>,
        crate::hook::ChannelTransport,
    ) {
        in_proc_pair(
            TaskKey::new("svc"),
            Priority::P1,
            SymbolResolver::new(SymbolTableModel::default()),
        )
    }

    #[test]
    fn register_round_trip() {
        let (mut client, server) = pair();
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let msg = ClientMsg::decode(&buf).unwrap();
            let ClientMsg::Register { task_key, priority, has_symbols, .. } = msg else {
                panic!("expected Register, got {msg:?}");
            };
            assert_eq!(priority, Priority::P1);
            assert!(has_symbols);
            let reply = SchedulerMsg::Registered {
                task_key,
                sharing_stage: true,
            };
            server.send(&reply.encode().unwrap()).unwrap();
        });
        assert!(client.register().unwrap());
        h.join().unwrap();
    }

    #[test]
    fn launch_decision_round_trip() {
        let (mut client, server) = pair();
        let kernel = KernelId::new("gemm", Dim3::x(8), Dim3::x(128));
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let ClientMsg::Launch { task_key, task_id, seq, kernel_name, .. } =
                ClientMsg::decode(&buf).unwrap()
            else {
                panic!("expected Launch");
            };
            assert_eq!(kernel_name, "gemm");
            let reply = SchedulerMsg::Hold { task_key: task_key.clone(), task_id, seq };
            server.send(&reply.encode().unwrap()).unwrap();
            // Later, release it.
            let release = SchedulerMsg::LaunchNow { task_key, task_id, seq };
            server.send(&release.encode().unwrap()).unwrap();
        });
        let decision = client
            .intercept_launch(&kernel, TaskId(3), 7, SimTime::ZERO)
            .unwrap();
        assert_eq!(decision, LaunchDecision::Held);
        client.wait_release(7).unwrap();
        h.join().unwrap();
    }

    /// A dropped reply triggers a byte-identical retransmit; the first
    /// answered attempt wins. (The backoff schedule changes *when*
    /// retransmits go out, never their bytes.)
    #[test]
    fn register_retransmits_until_answered() {
        let (mut client, server) = pair();
        client.set_retry(StdDuration::from_millis(30), 5);
        let h = std::thread::spawn(move || {
            // "Drop" the first datagram by ignoring it.
            let first = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let second = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            assert_eq!(first, second, "retransmit must be byte-identical");
            let ClientMsg::Register { task_key, .. } = ClientMsg::decode(&second).unwrap() else {
                panic!("expected Register");
            };
            let reply = SchedulerMsg::Registered {
                task_key,
                sharing_stage: false,
            };
            server.send(&reply.encode().unwrap()).unwrap();
        });
        assert!(!client.register().unwrap());
        h.join().unwrap();
    }

    /// Lifecycle messages block for the matching Ack, skipping stale
    /// out-of-band traffic; buffered releases satisfy a later
    /// wait_release without touching the wire.
    #[test]
    fn ack_matching_and_release_buffering() {
        let (mut client, server) = pair();
        client.set_retry(StdDuration::from_millis(200), 3);
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let (msg_seq, msg) = ClientMsg::decode_seq(&buf).unwrap();
            assert!(matches!(msg, ClientMsg::TaskStart { .. }));
            // Interleave an out-of-band release and a stale ack before
            // the real ack.
            let release = SchedulerMsg::LaunchNow {
                task_key: TaskKey::new("svc"),
                task_id: TaskId(0),
                seq: 9,
            };
            server.send(&release.encode().unwrap()).unwrap();
            server
                .send(&SchedulerMsg::Ack { msg_seq: msg_seq + 100 }.encode().unwrap())
                .unwrap();
            server
                .send(&SchedulerMsg::Ack { msg_seq }.encode().unwrap())
                .unwrap();
        });
        client.task_start(TaskId(0)).unwrap();
        h.join().unwrap();
        // The banked release resolves instantly — no server needed.
        client.set_retry(StdDuration::from_millis(10), 1);
        client.wait_release(9).unwrap();
    }

    #[test]
    fn timeout_is_an_error() {
        let (mut client, _server) = pair();
        client.set_retry(StdDuration::from_millis(5), 2);
        assert!(client.register().is_err());
    }

    /// The backoff schedule is exponential from 10 ms, capped, with
    /// bounded deterministic jitter.
    #[test]
    fn backoff_is_exponential_capped_and_jittered() {
        let (mut client, _server) = pair();
        client.set_retry(StdDuration::from_millis(80), 8);
        for (attempt, base_ms) in [(0u32, 10u64), (1, 20), (2, 40), (3, 80), (4, 80), (5, 80)] {
            let w = client.backoff_wait(attempt);
            let base = StdDuration::from_millis(base_ms);
            assert!(w >= base, "attempt {attempt}: {w:?} < base {base:?}");
            assert!(
                w < base + base / 4 + StdDuration::from_millis(1),
                "attempt {attempt}: jitter exceeds 25%: {w:?}"
            );
        }
        // Deterministic per client key: a rebuilt client with the same
        // key replays the identical jitter stream.
        let (mut a, _s1) = pair();
        let (mut b, _s2) = pair();
        let sched_a: Vec<_> = (0..6).map(|i| a.backoff_wait(i)).collect();
        let sched_b: Vec<_> = (0..6).map(|i| b.backoff_wait(i)).collect();
        assert_eq!(sched_a, sched_b);
    }

    /// wait_release against a dead node stops at the overall deadline
    /// instead of spinning through `attempts × timeout` forever.
    #[test]
    fn wait_release_respects_overall_deadline() {
        let (mut client, _server) = pair();
        // Generous per-attempt budget, tiny overall deadline.
        client.set_retry(StdDuration::from_millis(100), 50);
        client.set_release_deadline(StdDuration::from_millis(120));
        let start = Instant::now();
        assert!(client.wait_release(3).is_err());
        assert!(
            start.elapsed() < StdDuration::from_secs(2),
            "overall deadline must cut polling short, took {:?}",
            start.elapsed()
        );
    }

    /// A `RetryAfter` answer surfaces as an explicit `Error::Shed` with
    /// the daemon's reason — not a timeout, not a generic error.
    #[test]
    fn retry_after_surfaces_as_shed() {
        let (mut client, server) = pair();
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let ClientMsg::Register { task_key, .. } = ClientMsg::decode(&buf).unwrap() else {
                panic!("expected Register");
            };
            let reply = SchedulerMsg::RetryAfter {
                task_key,
                ms: 250,
                reason: "node at capacity".into(),
            };
            server.send(&reply.encode().unwrap()).unwrap();
        });
        let err = client.register().unwrap_err();
        h.join().unwrap();
        let Error::Shed(reason) = err else {
            panic!("expected Error::Shed, got {err:?}");
        };
        assert!(reason.contains("node at capacity"));
        assert!(reason.contains("250"));
    }

    /// A redirect to a known endpoint is followed transparently: the
    /// register lands on the named peer and the client sticks there.
    #[test]
    fn redirect_is_followed_to_named_endpoint() {
        let (t_a, server_a) = crate::hook::ChannelTransport::pair();
        let (t_b, server_b) = crate::hook::ChannelTransport::pair();
        let mut client = HookClient::new(
            t_a,
            TaskKey::new("svc"),
            Priority::P1,
            SymbolResolver::new(SymbolTableModel::default()),
        )
        .with_primary_name("n0");
        client.add_endpoint("n1", t_b);
        let h_a = std::thread::spawn(move || {
            let buf = server_a.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let ClientMsg::Register { task_key, .. } = ClientMsg::decode(&buf).unwrap() else {
                panic!("expected Register on n0");
            };
            let reply = SchedulerMsg::Redirect {
                task_key,
                node: "n1".into(),
            };
            server_a.send(&reply.encode().unwrap()).unwrap();
        });
        let h_b = std::thread::spawn(move || {
            let buf = server_b.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let ClientMsg::Register { task_key, .. } = ClientMsg::decode(&buf).unwrap() else {
                panic!("expected Register on n1");
            };
            let reply = SchedulerMsg::Registered {
                task_key,
                sharing_stage: false,
            };
            server_b.send(&reply.encode().unwrap()).unwrap();
        });
        assert!(!client.register().unwrap());
        assert_eq!(client.current_endpoint(), "n1");
        assert_eq!(client.failovers(), 0, "a redirect is not a failover");
        h_a.join().unwrap();
        h_b.join().unwrap();
    }

    /// When the current endpoint goes silent, the client fails over to
    /// the next endpoint and re-registers there before re-issuing the
    /// original request.
    #[test]
    fn failover_reestablishes_session_on_live_peer() {
        let (t_a, server_a) = crate::hook::ChannelTransport::pair();
        let (t_b, server_b) = crate::hook::ChannelTransport::pair();
        let mut client = HookClient::new(
            t_a,
            TaskKey::new("svc"),
            Priority::P1,
            SymbolResolver::new(SymbolTableModel::default()),
        )
        .with_primary_name("n0");
        client.add_endpoint("n1", t_b);
        client.set_retry(StdDuration::from_millis(15), 2);
        // n0 answers the initial register + task_start, then "dies"
        // (stops reading entirely).
        let h_a = std::thread::spawn(move || {
            let buf = server_a.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let ClientMsg::Register { task_key, .. } = ClientMsg::decode(&buf).unwrap() else {
                panic!("expected Register on n0");
            };
            server_a
                .send(
                    &SchedulerMsg::Registered { task_key, sharing_stage: false }
                        .encode()
                        .unwrap(),
                )
                .unwrap();
            let buf = server_a.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let (msg_seq, msg) = ClientMsg::decode_seq(&buf).unwrap();
            assert!(matches!(msg, ClientMsg::TaskStart { .. }));
            server_a
                .send(&SchedulerMsg::Ack { msg_seq }.encode().unwrap())
                .unwrap();
            // Dead from here on: never reads, never answers.
        });
        // n1 sees the failover: Register, TaskStart re-announcement,
        // then the Completion that triggered it all.
        let h_b = std::thread::spawn(move || {
            let buf = server_b.recv(StdDuration::from_secs(5)).unwrap().unwrap();
            let ClientMsg::Register { task_key, .. } = ClientMsg::decode(&buf).unwrap() else {
                panic!("failover must re-register first");
            };
            server_b
                .send(
                    &SchedulerMsg::Registered {
                        task_key,
                        sharing_stage: false,
                    }
                    .encode()
                    .unwrap(),
                )
                .unwrap();
            let buf = server_b.recv(StdDuration::from_secs(5)).unwrap().unwrap();
            let (msg_seq, msg) = ClientMsg::decode_seq(&buf).unwrap();
            assert!(
                matches!(msg, ClientMsg::TaskStart { .. }),
                "open task must be re-announced, got {msg:?}"
            );
            server_b
                .send(&SchedulerMsg::Ack { msg_seq }.encode().unwrap())
                .unwrap();
            let buf = server_b.recv(StdDuration::from_secs(5)).unwrap().unwrap();
            let (msg_seq, msg) = ClientMsg::decode_seq(&buf).unwrap();
            assert!(
                matches!(msg, ClientMsg::Completion { .. }),
                "original request re-issued after re-establishment, got {msg:?}"
            );
            server_b
                .send(&SchedulerMsg::Ack { msg_seq }.encode().unwrap())
                .unwrap();
        });
        assert!(!client.register().unwrap());
        client.task_start(TaskId(1)).unwrap();
        client
            .report_completion(TaskId(1), 0, crate::core::Duration::from_micros(5), SimTime(9))
            .unwrap();
        assert_eq!(client.current_endpoint(), "n1");
        assert_eq!(client.failovers(), 1);
        h_a.join().unwrap();
        h_b.join().unwrap();
    }
}
