//! The per-service hook client.
//!
//! In the paper this is the LD_PRELOADed library inside each service
//! container: it intercepts every kernel launch, resolves the kernel id
//! via the `-rdynamic` framework symbols, forwards the launch to the
//! FIKIT scheduler, and releases it to the GPU only when told to. Here it
//! fronts a [`Transport`] and is used by the real-time serving engine
//! (`runtime::engine`) and the UDP server integration tests.

use super::protocol::{ClientMsg, SchedulerMsg};
use super::transport::Transport;
use crate::core::{Dim3, Error, KernelId, Priority, Result, SimTime, TaskId, TaskKey};
use crate::profile::SymbolResolver;
use std::time::Duration as StdDuration;

/// Decision returned by the scheduler for one held launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchDecision {
    /// Launch to the GPU immediately.
    LaunchNow,
    /// Parked in a priority queue; a later `LaunchNow` will release it.
    Held,
}

/// Hook client state for one service process.
pub struct HookClient<T: Transport> {
    transport: T,
    task_key: TaskKey,
    priority: Priority,
    resolver: SymbolResolver,
    /// Scheduler-assigned stage from registration.
    sharing_stage: Option<bool>,
    recv_timeout: StdDuration,
}

impl<T: Transport> HookClient<T> {
    pub fn new(
        transport: T,
        task_key: TaskKey,
        priority: Priority,
        resolver: SymbolResolver,
    ) -> HookClient<T> {
        HookClient {
            transport,
            task_key,
            priority,
            resolver,
            sharing_stage: None,
            recv_timeout: StdDuration::from_millis(500),
        }
    }

    pub fn task_key(&self) -> &TaskKey {
        &self.task_key
    }

    /// Register with the scheduler; returns `true` if the service enters
    /// sharing stage (has a ready profile), `false` for measurement
    /// stage.
    pub fn register(&mut self) -> Result<bool> {
        let msg = ClientMsg::Register {
            task_key: self.task_key.clone(),
            priority: self.priority,
            has_symbols: self.resolver.model().symbols_exported,
        };
        self.transport.send(&msg.encode()?)?;
        match self.expect_reply()? {
            SchedulerMsg::Registered { sharing_stage, .. } => {
                self.sharing_stage = Some(sharing_stage);
                Ok(sharing_stage)
            }
            SchedulerMsg::Error { message } => Err(Error::Protocol(message)),
            other => Err(Error::Protocol(format!("unexpected reply: {other:?}"))),
        }
    }

    /// Announce a new task (invocation).
    pub fn task_start(&self, task_id: TaskId) -> Result<()> {
        let msg = ClientMsg::TaskStart {
            task_key: self.task_key.clone(),
            task_id,
        };
        self.transport.send(&msg.encode()?)
    }

    /// Intercept one kernel launch: resolve the kernel id, forward it,
    /// and return the scheduler's immediate decision.
    pub fn intercept_launch(
        &self,
        kernel: &KernelId,
        task_id: TaskId,
        seq: u32,
        now: SimTime,
    ) -> Result<LaunchDecision> {
        let (resolved, _cost) = self.resolver.resolve(kernel);
        let msg = ClientMsg::Launch {
            task_key: self.task_key.clone(),
            task_id,
            kernel_name: resolved.name.to_string(),
            grid: resolved.grid,
            block: resolved.block,
            seq,
            issued_at: now,
        };
        self.transport.send(&msg.encode()?)?;
        match self.expect_reply()? {
            SchedulerMsg::LaunchNow { .. } => Ok(LaunchDecision::LaunchNow),
            SchedulerMsg::Hold { .. } => Ok(LaunchDecision::Held),
            SchedulerMsg::Error { message } => Err(Error::Protocol(message)),
            other => Err(Error::Protocol(format!("unexpected reply: {other:?}"))),
        }
    }

    /// Wait for a deferred `LaunchNow` for a held kernel.
    pub fn wait_release(&self, seq: u32) -> Result<()> {
        loop {
            match self.expect_reply()? {
                SchedulerMsg::LaunchNow { seq: s, .. } if s == seq => return Ok(()),
                SchedulerMsg::LaunchNow { .. } | SchedulerMsg::Hold { .. } => continue,
                SchedulerMsg::Error { message } => return Err(Error::Protocol(message)),
                other => return Err(Error::Protocol(format!("unexpected reply: {other:?}"))),
            }
        }
    }

    /// Report a kernel completion (measurement stage / holder kernels).
    pub fn report_completion(
        &self,
        task_id: TaskId,
        seq: u32,
        exec: crate::core::Duration,
        finished_at: SimTime,
    ) -> Result<()> {
        let msg = ClientMsg::Completion {
            task_key: self.task_key.clone(),
            task_id,
            seq,
            exec,
            finished_at,
        };
        self.transport.send(&msg.encode()?)
    }

    /// Announce the current task finished.
    pub fn task_end(&self, task_id: TaskId) -> Result<()> {
        let msg = ClientMsg::TaskEnd {
            task_key: self.task_key.clone(),
            task_id,
        };
        self.transport.send(&msg.encode()?)
    }

    /// Clean shutdown.
    pub fn disconnect(&self) -> Result<()> {
        let msg = ClientMsg::Disconnect {
            task_key: self.task_key.clone(),
        };
        self.transport.send(&msg.encode()?)
    }

    fn expect_reply(&self) -> Result<SchedulerMsg> {
        match self.transport.recv(self.recv_timeout)? {
            Some(buf) => SchedulerMsg::decode(&buf),
            None => Err(Error::Protocol("scheduler reply timed out".into())),
        }
    }

    /// Erase a kernel id through the client's resolver (test helper).
    pub fn resolve(&self, kernel: &KernelId) -> KernelId {
        self.resolver.resolve(kernel).0
    }
}

/// Convenience constructor for an in-proc client/server pair used by
/// tests and the real-time engine.
pub fn in_proc_pair(
    task_key: TaskKey,
    priority: Priority,
    resolver: SymbolResolver,
) -> (HookClient<super::transport::ChannelTransport>, super::transport::ChannelTransport) {
    let (client_t, server_t) = super::transport::ChannelTransport::pair();
    (
        HookClient::new(client_t, task_key, priority, resolver),
        server_t,
    )
}

/// Build a [`KernelId`] from the wire fields of a `Launch` message.
pub fn kernel_id_from_wire(kernel_name: &str, grid: Dim3, block: Dim3) -> KernelId {
    KernelId::new(kernel_name.to_string(), grid, block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hook::protocol::ClientMsg;
    use crate::hook::transport::Transport;
    use crate::profile::SymbolTableModel;

    fn pair() -> (
        HookClient<crate::hook::ChannelTransport>,
        crate::hook::ChannelTransport,
    ) {
        in_proc_pair(
            TaskKey::new("svc"),
            Priority::P1,
            SymbolResolver::new(SymbolTableModel::default()),
        )
    }

    #[test]
    fn register_round_trip() {
        let (mut client, server) = pair();
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let msg = ClientMsg::decode(&buf).unwrap();
            let ClientMsg::Register { task_key, priority, has_symbols } = msg else {
                panic!("expected Register, got {msg:?}");
            };
            assert_eq!(priority, Priority::P1);
            assert!(has_symbols);
            let reply = SchedulerMsg::Registered {
                task_key,
                sharing_stage: true,
            };
            server.send(&reply.encode().unwrap()).unwrap();
        });
        assert!(client.register().unwrap());
        h.join().unwrap();
    }

    #[test]
    fn launch_decision_round_trip() {
        let (client, server) = pair();
        let kernel = KernelId::new("gemm", Dim3::x(8), Dim3::x(128));
        let h = std::thread::spawn(move || {
            let buf = server.recv(StdDuration::from_secs(1)).unwrap().unwrap();
            let ClientMsg::Launch { task_key, task_id, seq, kernel_name, .. } =
                ClientMsg::decode(&buf).unwrap()
            else {
                panic!("expected Launch");
            };
            assert_eq!(kernel_name, "gemm");
            let reply = SchedulerMsg::Hold { task_key: task_key.clone(), task_id, seq };
            server.send(&reply.encode().unwrap()).unwrap();
            // Later, release it.
            let release = SchedulerMsg::LaunchNow { task_key, task_id, seq };
            server.send(&release.encode().unwrap()).unwrap();
        });
        let decision = client
            .intercept_launch(&kernel, TaskId(3), 7, SimTime::ZERO)
            .unwrap();
        assert_eq!(decision, LaunchDecision::Held);
        client.wait_release(7).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn timeout_is_an_error() {
        let (mut client, _server) = pair();
        client.recv_timeout = StdDuration::from_millis(10);
        assert!(client.register().is_err());
    }
}
