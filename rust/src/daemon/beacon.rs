//! Outgoing capacity/health beacons for a federated daemon node.
//!
//! A node joins a fleet by doing exactly two things: emitting a
//! [`PeerMsg::Beacon`] every `beacon_interval` on its peer links, and
//! folding received beacons into its `FleetView`
//! (`cluster::control`). The [`Beaconer`] owns the outgoing half: the
//! cadence clock and the per-node monotonic beacon sequence receivers
//! dedup on. It is polled from the daemon's serve loop (between
//! datagrams, off the launch hot path) rather than from a timer
//! thread, so a single-threaded daemon stays single-threaded
//! (DESIGN.md §Fleet-federation, ADR-005).

use crate::core::{Duration, SimTime};
use crate::hook::PeerMsg;

/// Capacity snapshot advertised in one beacon.
#[derive(Debug, Clone, Copy)]
pub struct Advertised {
    pub devices: u32,
    pub capacity: u32,
    pub residents: u32,
    pub draining: bool,
}

/// Emits this node's beacons on a fixed cadence.
#[derive(Debug)]
pub struct Beaconer {
    node: String,
    interval: Duration,
    /// Monotonic beacon sequence; receivers drop `<=` last seen.
    seq: u64,
    last_sent: Option<SimTime>,
}

impl Beaconer {
    pub fn new(node: &str, interval: Duration) -> Beaconer {
        Beaconer {
            node: node.to_string(),
            interval,
            seq: 0,
            last_sent: None,
        }
    }

    pub fn node(&self) -> &str {
        &self.node
    }

    /// Emit a beacon if one is due at `now` (the first poll always
    /// emits, so a freshly started node announces itself immediately —
    /// that is what re-enters a restarted node into peers' views).
    pub fn poll(&mut self, now: SimTime, adv: Advertised) -> Option<PeerMsg> {
        let due = match self.last_sent {
            None => true,
            Some(last) => now.nanos().saturating_sub(last.nanos()) >= self.interval.nanos(),
        };
        if !due {
            return None;
        }
        self.last_sent = Some(now);
        self.seq += 1;
        Some(PeerMsg::Beacon {
            node: self.node.clone(),
            seq: self.seq,
            sent_at_ns: now.nanos(),
            devices: adv.devices,
            capacity: adv.capacity,
            residents: adv.residents,
            draining: adv.draining,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_and_monotonic_seq() {
        let mut b = Beaconer::new("n0", Duration::from_millis(100));
        let adv = Advertised {
            devices: 1,
            capacity: 4,
            residents: 2,
            draining: false,
        };
        let t = |ms: u64| SimTime(ms * 1_000_000);
        // First poll emits immediately (startup announcement).
        let Some(PeerMsg::Beacon { seq, node, residents, .. }) = b.poll(t(0), adv) else {
            panic!("first poll must emit");
        };
        assert_eq!((seq, node.as_str(), residents), (1, "n0", 2));
        // Not due again until a full interval has passed.
        assert!(b.poll(t(50), adv).is_none());
        assert!(b.poll(t(99), adv).is_none());
        let Some(PeerMsg::Beacon { seq, .. }) = b.poll(t(100), adv) else {
            panic!("due at the interval");
        };
        assert_eq!(seq, 2);
        // Seq never repeats across a long run.
        let mut last = seq;
        for ms in (200..2000).step_by(100) {
            if let Some(PeerMsg::Beacon { seq, .. }) = b.poll(t(ms), adv) {
                assert!(seq > last);
                last = seq;
            }
        }
    }
}
