//! The sharded FIKIT scheduler daemon (DESIGN.md §Daemon).
//!
//! The paper's deployment shape is a standalone scheduler process hook
//! clients talk to over UDP. This module grows that from a single-device
//! control plane into a fleet daemon:
//!
//! * [`Shard`] — one per GPU; owns that device's `PriorityQueues`,
//!   `FillWindow`, `Interner` and active set (the whole FIKIT control
//!   plane), pure of any socket;
//! * [`Registry`] — admits services and routes them to shards through
//!   [`crate::cluster::placement::FleetState`] capacity accounting,
//!   and keeps the per-client retransmit-dedup + released-sequence
//!   state;
//! * [`SchedulerDaemon`] — decodes datagrams, deduplicates retransmits
//!   (protocol v2 `msg_seq`), dispatches to the owning shard and routes
//!   the shard's outbound messages back to client addresses.
//!
//! The daemon is transport-generic ([`ServerTransport`]): production
//! runs it over UDP (`fikit serve --devices N`), tests run it over the
//! deterministic in-process [`crate::hook::transport::LossyNet`] to
//! prove dropped-datagram recovery without real sockets.
//!
//! ## Durable sessions (ADR-004)
//!
//! With `fikit serve --journal <dir>` the daemon write-ahead journals
//! every applied session-lifecycle message ([`journal`]) *before* the
//! registry/shard mutation is acknowledged, and snapshots + truncates
//! periodically. [`SchedulerDaemon::with_journal`] replays snapshot +
//! tail on startup, reconstructing the registry, per-shard capacity
//! accounting, open fill windows AND the per-client `msg_seq` dedup
//! state — so clients reconnect through their ordinary retry loop and
//! byte-identical retransmits that straddle the restart are still
//! absorbed, not re-executed. Replay is deterministic because every
//! record carries the wall-clock `now` the daemon processed it at and
//! the whole `handle` path is a pure function of (message, now, state);
//! `tests/daemon_recovery.rs` proves convergence from every scripted
//! crash point.

pub mod beacon;
pub mod journal;
pub mod registry;
pub mod shard;

pub use beacon::{Advertised, Beaconer};
pub use journal::{CrashPoint, FaultPlan, Journal, JournalConfig, JournalRecord};
pub use registry::{Admission, ClientEntry, Registry};
pub use shard::{ServerStats, Shard, ShardSizes};

use crate::cluster::control::{FleetConfig, FleetView};
use crate::cluster::placement::PlacementPolicy;
use crate::coordinator::fikit::DEFAULT_EPSILON;
use crate::core::{Duration, Error, Result, SimTime, TaskKey};
use crate::hook::protocol::{self, ClientMsg, PeerMsg, SchedulerMsg};
use crate::hook::transport::{ServerTransport, Transport};
use crate::profile::ProfileStore;
use crate::util::json::Json;
use std::net::SocketAddr;
use std::path::Path;
use std::time::{Duration as StdDuration, Instant};

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// GPU devices served — one shard each.
    pub devices: usize,
    /// Concurrent services a device may host (admission bound).
    pub capacity: usize,
    /// Placement policy routing services to shards.
    pub policy: PlacementPolicy,
    /// Small-gap threshold ε.
    pub epsilon: Duration,
    /// Runs required before a profile counts as ready.
    pub min_profile_runs: u32,
    /// Online sharing-stage profile refinement, one refiner per shard
    /// (DESIGN.md §9). Off by default — `fikit serve --online` enables
    /// it; refined profiles shadow the loaded store and persist via
    /// [`SchedulerDaemon::save_profiles`].
    pub online: crate::profile::OnlineConfig,
    /// Fleet membership: this node's advertised name (`fikit serve
    /// --advertise n0`). `None` = standalone daemon — no beacons are
    /// emitted and over-capacity registers shed with `RetryAfter`
    /// (there is no peer to redirect to).
    pub node: Option<String>,
    /// Control-plane tuning (beacon cadence, failure-detection
    /// threshold, shed back-off hint) — DESIGN.md §Fleet-federation.
    pub fleet: FleetConfig,
}

impl Default for DaemonConfig {
    fn default() -> DaemonConfig {
        DaemonConfig {
            devices: 1,
            capacity: 32,
            policy: PlacementPolicy::LeastLoaded,
            epsilon: DEFAULT_EPSILON,
            min_profile_runs: 1,
            online: crate::profile::OnlineConfig::default(),
            node: None,
            fleet: FleetConfig::default(),
        }
    }
}

/// Wire/routing counters (the shards keep the scheduling counters).
#[derive(Debug, Clone, Default)]
pub struct DaemonStats {
    /// Datagrams that failed to decode.
    pub decode_errors: u64,
    /// Retransmits absorbed by the dedup layer (reply re-sent or stale
    /// frame dropped; side effects not re-executed).
    pub duplicates: u64,
    /// Non-`Register` messages from services with no registry entry.
    pub unknown_service: u64,
    /// `Register` attempts turned away because every device was full.
    pub rejected_capacity: u64,
    /// Releases minted by a shard whose client had vanished by routing
    /// time — previously dropped silently in `pump_fills`, now counted.
    pub releases_unroutable: u64,
    /// Refined profiles harvested from shards and installed over the
    /// loaded store (online refinement; DESIGN.md §9).
    pub profiles_refined: u64,
    /// Over-capacity registers answered with `Redirect{node}` (a live
    /// peer advertised room).
    pub redirects: u64,
    /// Over-capacity registers answered with `RetryAfter` (no live
    /// non-draining peer had room) — explicit load shedding.
    pub sheds: u64,
    /// Beacons emitted on peer links.
    pub beacons_sent: u64,
    /// Peer beacons received and folded into the fleet view…
    pub beacons_received: u64,
    /// …and received but dropped by the per-peer seq guard
    /// (duplicated / reordered / delayed deliveries).
    pub beacons_stale: u64,
}

/// The sharded scheduler daemon: registry + one shard per device.
pub struct SchedulerDaemon {
    cfg: DaemonConfig,
    profiles: ProfileStore,
    registry: Registry,
    shards: Vec<Shard>,
    stats: DaemonStats,
    epoch: Instant,
    /// Write-ahead session journal (ADR-004); `None` = ephemeral daemon.
    journal: Option<Journal>,
    /// True while startup replay re-runs journaled records through the
    /// ordinary `handle_at` path — suppresses re-journaling and
    /// snapshotting of what is already durable.
    replaying: bool,
    /// An injected [`FaultPlan`] tripped (or a journal write failed):
    /// the daemon is fail-stop from here — it must not apply or
    /// acknowledge anything it could not journal first.
    crashed: bool,
    /// Virtual-time offset: `now()` = `base_ns` + elapsed since process
    /// start. Recovery sets it past every replayed timestamp so time
    /// never runs backwards across a restart (no resurrected windows).
    base_ns: u64,
    /// This node's picture of its peers, folded from received beacons.
    /// Control-plane state only: never journaled, never part of
    /// `state_json` — a restarted node rebuilds it from live beacons
    /// within one beacon interval (ADR-005).
    fleet_view: FleetView,
    /// Outgoing beacon clock+seq; `None` for a standalone daemon.
    beaconer: Option<Beaconer>,
    /// Client-shaped links to each peer daemon, used only to send
    /// beacons (peer frames arrive on the ordinary server transport and
    /// are forked off by the frame kind byte).
    peer_links: Vec<Box<dyn Transport>>,
    /// Draining for shutdown: advertised in beacons so peers stop
    /// redirecting here.
    draining: bool,
}

impl SchedulerDaemon {
    pub fn new(cfg: DaemonConfig, profiles: ProfileStore) -> SchedulerDaemon {
        assert!(cfg.devices > 0, "daemon needs at least one device");
        let registry = Registry::new(cfg.devices, cfg.capacity, cfg.policy);
        let shards = (0..cfg.devices)
            .map(|_| Shard::with_online(cfg.epsilon, cfg.online.clone()))
            .collect();
        let fleet_view = FleetView::new(cfg.fleet);
        let beaconer = cfg
            .node
            .as_ref()
            .map(|n| Beaconer::new(n, cfg.fleet.beacon_interval));
        SchedulerDaemon {
            cfg,
            profiles,
            registry,
            shards,
            stats: DaemonStats::default(),
            epoch: Instant::now(),
            journal: None,
            replaying: false,
            crashed: false,
            base_ns: 0,
            fleet_view,
            beaconer,
            peer_links: Vec::new(),
            draining: false,
        }
    }

    /// A durable daemon: open (or create) the session journal in `dir`,
    /// restore the latest snapshot, replay the record tail through the
    /// ordinary message path, and resume with virtual time strictly
    /// after every replayed timestamp. The restored state includes each
    /// client's `msg_seq` dedup baseline and cached replies, so
    /// retransmits that straddle the restart are absorbed exactly as if
    /// the daemon had never died (ADR-004).
    pub fn with_journal(
        cfg: DaemonConfig,
        profiles: ProfileStore,
        dir: impl AsRef<Path>,
        jcfg: JournalConfig,
    ) -> Result<SchedulerDaemon> {
        let recovered = Journal::open(dir, jcfg)?;
        let mut daemon = SchedulerDaemon::new(cfg, profiles);
        let mut base_ns = 0u64;
        if let Some(doc) = &recovered.snapshot {
            base_ns = doc.req_u64("now_ns")?;
            daemon.restore_state(doc.require("state")?)?;
        }
        daemon.journal = Some(recovered.journal);
        daemon.replaying = true;
        for rec in &recovered.tail {
            let rec_ns = daemon.replay_record(rec)?;
            base_ns = base_ns.max(rec_ns);
        }
        daemon.replaying = false;
        daemon.base_ns = base_ns.saturating_add(1);
        daemon.epoch = Instant::now();
        Ok(daemon)
    }

    /// Wire/routing counters.
    pub fn stats(&self) -> &DaemonStats {
        &self.stats
    }

    /// One shard's scheduling counters.
    pub fn shard_stats(&self, device: usize) -> &ServerStats {
        self.shards[device].stats()
    }

    /// Fleet-wide scheduling counters (field-wise sum over shards).
    pub fn stats_total(&self) -> ServerStats {
        let mut total = ServerStats::default();
        for s in &self.shards {
            total.add(s.stats());
        }
        total
    }

    /// Number of shards (devices).
    pub fn devices(&self) -> usize {
        self.shards.len()
    }

    /// Shard hosting `key`, if registered.
    pub fn shard_of(&self, key: &TaskKey) -> Option<usize> {
        self.registry.get(key).map(|e| e.shard)
    }

    /// Fill windows currently open across the fleet.
    pub fn open_windows(&self) -> usize {
        self.shards.iter().filter(|s| s.window_open()).count()
    }

    /// Map sizes per shard (leak probes for tests).
    pub fn shard_sizes(&self) -> Vec<ShardSizes> {
        self.shards.iter().map(Shard::sizes).collect()
    }

    /// Registered clients.
    pub fn clients(&self) -> usize {
        self.registry.len()
    }

    /// Direct access for tests that probe a shard.
    pub fn shard(&self, device: usize) -> &Shard {
        &self.shards[device]
    }

    fn now(&self) -> SimTime {
        SimTime(self.base_ns + self.epoch.elapsed().as_nanos() as u64)
    }

    /// Whether an injected fault (or journal write failure) has killed
    /// this daemon — fail-stop: a crashed daemon applies nothing more.
    pub fn crashed(&self) -> bool {
        self.crashed
    }

    /// The session journal, if this daemon is durable.
    pub fn journal(&self) -> Option<&Journal> {
        self.journal.as_ref()
    }

    /// Mutable journal access (crash-injection tests arm [`FaultPlan`]s
    /// through this).
    pub fn journal_mut(&mut self) -> Option<&mut Journal> {
        self.journal.as_mut()
    }

    /// This node's picture of its peers (read-only; tests and the churn
    /// scenario assert re-entry of restarted nodes through it).
    pub fn fleet_view(&self) -> &FleetView {
        &self.fleet_view
    }

    /// Attach a send-only link to one peer daemon; this node's beacons
    /// will be emitted on every attached link.
    pub fn add_peer_link(&mut self, link: Box<dyn Transport>) {
        self.peer_links.push(link);
    }

    /// Peers currently passing missed-beacon failure detection, by this
    /// daemon's own clock (the `fikit serve` stats line prints it; the
    /// churn scenario asserts partition healing through it).
    pub fn live_peers(&self) -> usize {
        self.fleet_view.live_peers(self.now())
    }

    /// Begin draining: keep serving resident sessions, but advertise
    /// `draining` so peers stop redirecting new work here.
    pub fn set_draining(&mut self, draining: bool) {
        self.draining = draining;
    }

    /// Fold one peer beacon in as if it had arrived on the wire at
    /// `now` (tests and the fleet-view unit drive this directly; the
    /// serve loop reaches it through [`SchedulerDaemon::handle_datagram`]).
    pub fn observe_beacon_at(&mut self, beacon: &PeerMsg, now: SimTime) {
        self.stats.beacons_received += 1;
        if !self.fleet_view.observe(beacon, now) {
            self.stats.beacons_stale += 1;
        }
    }

    /// Emit this node's beacon on every peer link when one is due.
    /// Called from the serve loop between datagrams — the control plane
    /// never runs on the launch hot path, and a standalone daemon
    /// (no `cfg.node`) pays one branch.
    fn pump_beacons(&mut self) {
        if self.beaconer.is_none() || self.crashed {
            return;
        }
        let now = self.now();
        let adv = Advertised {
            devices: self.cfg.devices as u32,
            capacity: self.cfg.capacity as u32,
            residents: self.registry.total_residents() as u32,
            draining: self.draining,
        };
        let Some(msg) = self.beaconer.as_mut().expect("checked above").poll(now, adv) else {
            return;
        };
        if let Ok(bytes) = msg.encode() {
            for link in &self.peer_links {
                // Beacons are gossip: losses are repaired by the next
                // cadence tick, so send errors are deliberately dropped.
                if link.send(&bytes).is_ok() {
                    self.stats.beacons_sent += 1;
                }
            }
        }
    }

    /// Serve datagrams from `transport` until `deadline` elapses
    /// (`None` = forever). With `exit_when_drained`, also return once
    /// every client that ever registered has disconnected — the clean
    /// shutdown tests and `LossyNet` runs use.
    pub fn serve<T: ServerTransport>(
        &mut self,
        transport: &T,
        deadline: Option<StdDuration>,
        exit_when_drained: bool,
    ) -> Result<()> {
        self.serve_limited(transport, deadline, exit_when_drained, None)
    }

    /// [`SchedulerDaemon::serve`] with an optional datagram budget: stop
    /// after handling `max_datagrams` frames. The restart tests use this
    /// to cut a daemon off mid-traffic at a deterministic point; an
    /// injected-fault "death" ([`SchedulerDaemon::crashed`]) also ends
    /// the loop.
    pub fn serve_limited<T: ServerTransport>(
        &mut self,
        transport: &T,
        deadline: Option<StdDuration>,
        exit_when_drained: bool,
        max_datagrams: Option<u64>,
    ) -> Result<()> {
        let start = Instant::now();
        // A journal-recovered daemon may begin life with live sessions:
        // they count as "had clients" for drain-exit purposes.
        let mut had_clients = !self.registry.is_empty();
        let mut handled: u64 = 0;
        loop {
            if self.crashed {
                return Ok(());
            }
            if max_datagrams.is_some_and(|n| handled >= n) {
                return Ok(());
            }
            if let Some(d) = deadline {
                if start.elapsed() >= d {
                    return Ok(());
                }
            }
            if exit_when_drained && had_clients && self.registry.is_empty() {
                return Ok(());
            }
            self.pump_beacons();
            match transport.recv_from(StdDuration::from_millis(20))? {
                Some((buf, addr)) => {
                    handled += 1;
                    for (to, reply) in self.handle_datagram(&buf, addr) {
                        if let Ok(bytes) = reply.encode() {
                            transport.send_to(&bytes, to).ok();
                        }
                    }
                    had_clients |= !self.registry.is_empty();
                }
                None => continue,
            }
        }
    }

    /// Decode one datagram and handle it; returns the replies to send.
    ///
    /// Peer control-plane frames (`KIND_PEER`) are forked off *before*
    /// the client decode: they update the fleet view and nothing else —
    /// no reply, no journal record, no dedup state — so the federation
    /// layer cannot perturb ADR-004 replay determinism.
    pub fn handle_datagram(
        &mut self,
        buf: &[u8],
        addr: SocketAddr,
    ) -> Vec<(SocketAddr, SchedulerMsg)> {
        if protocol::frame_kind(buf) == Some(protocol::KIND_PEER) {
            match PeerMsg::decode(buf) {
                Ok(beacon) => {
                    let now = self.now();
                    self.observe_beacon_at(&beacon, now);
                }
                Err(_) => self.stats.decode_errors += 1,
            }
            return Vec::new();
        }
        match ClientMsg::decode_seq(buf) {
            Ok((msg_seq, msg)) => self.handle(msg_seq, msg, addr),
            Err(e) => {
                self.stats.decode_errors += 1;
                vec![(
                    addr,
                    SchedulerMsg::Error {
                        message: e.to_string(),
                    },
                )]
            }
        }
    }

    /// Handle one decoded message; returns the replies to send. The
    /// dedup layer makes every retransmit (same `msg_seq`) safe: the
    /// cached reply is re-sent and side effects are not re-executed.
    pub fn handle(
        &mut self,
        msg_seq: u64,
        msg: ClientMsg,
        addr: SocketAddr,
    ) -> Vec<(SocketAddr, SchedulerMsg)> {
        let now = self.now();
        self.handle_at(msg_seq, msg, addr, now)
    }

    /// [`SchedulerDaemon::handle`] at an explicit timestamp — the
    /// journal-replay entry point (ADR-004): every journaled record
    /// carries the `now` it was originally processed at, and replaying
    /// through this exact path (same dedup checks, same shard calls,
    /// same routing) is what makes recovery deterministic. Tests also
    /// use it to drive the daemon on a synthetic clock.
    pub fn handle_at(
        &mut self,
        msg_seq: u64,
        msg: ClientMsg,
        addr: SocketAddr,
        now: SimTime,
    ) -> Vec<(SocketAddr, SchedulerMsg)> {
        if self.crashed {
            return Vec::new(); // a dead process answers nothing
        }
        let msg = match msg {
            ClientMsg::Register {
                task_key,
                priority,
                has_symbols,
                model,
            } => {
                return self
                    .handle_register(msg_seq, task_key, priority, has_symbols, model, addr, now)
            }
            other => other,
        };

        let key = msg.task_key().clone();
        // Dedup / unknown-service guards, in a scope so the entry borrow
        // ends before the journal append. Nothing in here mutates state,
        // so none of it is journaled: replay never sees duplicates — the
        // journal IS the post-dedup stream.
        let (shard_idx, prio) = {
            let Some(entry) = self.registry.get(&key) else {
                // Disconnect for an unknown service is already done — ack
                // it so a client whose first Disconnect datagram was
                // processed (but whose ack was dropped) converges on
                // retransmit.
                if matches!(msg, ClientMsg::Disconnect { .. }) {
                    return vec![(addr, SchedulerMsg::Ack { msg_seq })];
                }
                self.stats.unknown_service += 1;
                return vec![(
                    addr,
                    SchedulerMsg::Error {
                        message: format!("service {:?} is not registered", key.as_str()),
                    },
                )];
            };
            if msg_seq < entry.last_msg_seq {
                self.stats.duplicates += 1;
                return Vec::new(); // stale straggler
            }
            if msg_seq == entry.last_msg_seq {
                // Retransmit: re-send what the original processing
                // answered.
                self.stats.duplicates += 1;
                let to = entry.addr;
                return entry.last_replies.iter().cloned().map(|m| (to, m)).collect();
            }
            (entry.shard, entry.priority)
        };
        // Write-ahead point: the record must be durable before any
        // mutation below executes or is acknowledged. An injected crash
        // (or write failure) here means the message was never applied —
        // the client retransmits and the restarted daemon processes it
        // fresh (or replays it, if the append completed).
        if !self.wal_apply(msg_seq, &msg, addr, now) {
            return Vec::new();
        }
        let entry = self.registry.get_mut(&key).expect("presence checked above");
        entry.last_msg_seq = msg_seq;
        entry.addr = addr;

        let produced: Vec<SchedulerMsg> = match msg {
            ClientMsg::Register { .. } => unreachable!("handled above"),
            ClientMsg::TaskStart { task_key, .. } => {
                self.shards[shard_idx].task_start(&task_key, prio);
                vec![SchedulerMsg::Ack { msg_seq }]
            }
            ClientMsg::TaskEnd { task_key, .. } => {
                // Seqs may be reused by the service's next task.
                if let Some(e) = self.registry.get_mut(&task_key) {
                    e.released.clear();
                }
                let mut out = self.shards[shard_idx].task_end(&task_key);
                out.push(SchedulerMsg::Ack { msg_seq });
                out
            }
            ClientMsg::Launch {
                task_key,
                task_id,
                kernel_name,
                grid,
                block,
                seq,
                ..
            } => {
                let kernel = crate::hook::client::kernel_id_from_wire(&kernel_name, grid, block);
                self.shards[shard_idx].launch(
                    &task_key,
                    prio,
                    task_id,
                    kernel,
                    seq,
                    &self.profiles,
                    now,
                )
            }
            ClientMsg::Completion {
                task_key,
                seq,
                exec,
                ..
            } => {
                let mut out =
                    self.shards[shard_idx].completion(&task_key, seq, exec, &self.profiles, now);
                // Route the shard's measured execution dilations into the
                // registry's interference model (ADR-006) so placement
                // learns from this fleet's own co-residency. Deterministic:
                // a pure function of the message stream, like the rest of
                // `handle`, so journal replay rebuilds the same estimates.
                for (victim, dilation) in self.shards[shard_idx].take_dilations() {
                    self.registry.observe_interference(&victim, dilation);
                }
                out.push(SchedulerMsg::Ack { msg_seq });
                out
            }
            ClientMsg::Preempted {
                task_key,
                task_id,
                kernel_name,
                grid,
                block,
                seq,
                remaining,
            } => {
                // The launch is held again: its seq leaves the released
                // record (a `ReleaseQuery` must answer `Hold`, not
                // `LaunchNow`) until `route` re-adds it when the remnant
                // is eventually re-released.
                if let Some(e) = self.registry.get_mut(&task_key) {
                    e.released.remove(&seq);
                }
                let kernel = crate::hook::client::kernel_id_from_wire(&kernel_name, grid, block);
                let mut out = self.shards[shard_idx].repark(
                    &task_key, prio, task_id, kernel, seq, remaining, now,
                );
                out.push(SchedulerMsg::Ack { msg_seq });
                out
            }
            ClientMsg::Disconnect { task_key } => {
                self.registry.disconnect(&task_key);
                let mut out = self.shards[shard_idx].disconnect(&task_key);
                out.push(SchedulerMsg::Ack { msg_seq });
                out
            }
            ClientMsg::ReleaseQuery { task_key, seq } => {
                // Pure query — answered from the released record / queue
                // state, no side effects.
                let entry = self.registry.get(&task_key).expect("checked above");
                if entry.released.contains(&seq) {
                    vec![SchedulerMsg::LaunchNow {
                        task_key,
                        task_id: crate::core::TaskId(0),
                        seq,
                    }]
                } else if self.shards[shard_idx].is_queued(&task_key, seq) {
                    vec![SchedulerMsg::Hold {
                        task_key,
                        task_id: crate::core::TaskId(0),
                        seq,
                    }]
                } else {
                    vec![SchedulerMsg::Error {
                        message: format!("launch seq {seq} is unknown (never held or purged)"),
                    }]
                }
            }
        };
        // Harvest any profiles the shard's refiner republished while
        // processing this message: they shadow the loaded store
        // immediately (subsequent SK/SG lookups see refreshed numbers)
        // and are what `save_profiles` persists across restarts.
        let refined = self.shards[shard_idx].take_refined(&self.profiles);
        if !refined.is_empty() {
            self.stats.profiles_refined += refined.len() as u64;
            for p in refined {
                self.profiles.insert(p);
            }
        }
        let out = self.route(&key, msg_seq, addr, produced);
        self.maybe_snapshot(now);
        out
    }

    /// The daemon's live profile store (loaded offline profiles plus
    /// any refined overlays installed since).
    pub fn profiles(&self) -> &ProfileStore {
        &self.profiles
    }

    /// The admission registry, including the interference model learned
    /// from this fleet's completion dilations (ADR-006).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Persist the live store — including refined epochs — so a
    /// restarted daemon resumes from the refined predictions instead of
    /// the stale offline ones (versioned format: profile-format.md).
    pub fn save_profiles(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.profiles.save(path)
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_register(
        &mut self,
        msg_seq: u64,
        task_key: TaskKey,
        priority: crate::core::Priority,
        has_symbols: bool,
        model: Option<String>,
        addr: SocketAddr,
        now: SimTime,
    ) -> Vec<(SocketAddr, SchedulerMsg)> {
        // Retransmit / straggler handling. From the SAME address, only a
        // Register with msg_seq > last is a genuine (in-session)
        // re-registration: an equal sequence is a byte-identical
        // retransmit (replay the cached reply), and a smaller one is a
        // delayed duplicate from earlier in the session — processing it
        // would rewind the dedup baseline and wipe the released-seq
        // record mid-task, so it is dropped. A DIFFERENT address is a
        // restarted client and is always processed (its initial msg_seq
        // may collide with the old session's).
        if let Some(entry) = self.registry.get(&task_key) {
            if entry.addr == addr && msg_seq <= entry.last_msg_seq {
                self.stats.duplicates += 1;
                if msg_seq == entry.last_msg_seq {
                    let to = entry.addr;
                    return entry.last_replies.iter().cloned().map(|m| (to, m)).collect();
                }
                return Vec::new(); // stale straggler
            }
        }
        // Write-ahead point (post-dedup, like every journaled message):
        // the Register must be durable before the registry mutates.
        let wal_msg = ClientMsg::Register {
            task_key: task_key.clone(),
            priority,
            has_symbols,
            model: model.clone(),
        };
        if !self.wal_apply(msg_seq, &wal_msg, addr, now) {
            return Vec::new();
        }
        match self
            .registry
            .register(&task_key, priority, model.as_deref(), addr, msg_seq)
        {
            Admission::Rejected => {
                // Never a silent rejection and never an unbounded queue:
                // the client gets either a named live peer with room
                // (follow the redirect) or an explicit shed with a
                // back-off hint and a reason (satellite of ISSUE 8;
                // ADR-005 §shed-vs-redirect).
                self.stats.rejected_capacity += 1;
                match self.fleet_view.best_redirect(now).map(str::to_string) {
                    Some(node) => {
                        self.stats.redirects += 1;
                        vec![(addr, SchedulerMsg::Redirect { task_key, node })]
                    }
                    None => {
                        self.stats.sheds += 1;
                        vec![(
                            addr,
                            SchedulerMsg::RetryAfter {
                                task_key,
                                ms: self.cfg.fleet.retry_after_ms,
                                reason: format!(
                                    "node at capacity ({} devices × {} services) and no \
                                     live peer has room",
                                    self.cfg.devices, self.cfg.capacity
                                ),
                            },
                        )]
                    }
                }
            }
            admission @ (Admission::Placed(_) | Admission::Refreshed(_)) => {
                let shard = match admission {
                    Admission::Placed(s) | Admission::Refreshed(s) => s,
                    Admission::Rejected => unreachable!("matched above"),
                };
                // A fresh placement also journals its decision (shard +
                // service id), appended *after* the placement is known.
                // Replay recomputes placement deterministically from the
                // Apply record; the Admit record lets it verify
                // convergence and fail loudly on divergence instead of
                // silently rebuilding a different fleet.
                if matches!(admission, Admission::Placed(_)) {
                    let service_id = self
                        .registry
                        .get(&task_key)
                        .expect("just placed")
                        .service_id;
                    if !self.wal_admit(&task_key, shard, service_id) {
                        return Vec::new();
                    }
                }
                self.shards[shard].stats_mut().registered += 1;
                // Without exported symbols kernels cannot be identified —
                // profiles would be meaningless (paper §3.2), so such
                // services never reach sharing stage.
                let sharing = has_symbols
                    && self
                        .profiles
                        .has_ready(&task_key, self.cfg.min_profile_runs);
                let reply = SchedulerMsg::Registered {
                    task_key: task_key.clone(),
                    sharing_stage: sharing,
                };
                let out = self.route(&task_key, msg_seq, addr, vec![reply]);
                self.maybe_snapshot(now);
                out
            }
        }
    }

    /// Address each produced message: by its own task key for
    /// `LaunchNow`/`Hold`/`Registered`, to the sender for `Ack`/`Error`.
    /// Messages addressed to the sender are cached for retransmit
    /// replay; `LaunchNow` routing records the seq in the target's
    /// released set (the `ReleaseQuery` answer book).
    fn route(
        &mut self,
        sender: &TaskKey,
        msg_seq: u64,
        sender_addr: SocketAddr,
        produced: Vec<SchedulerMsg>,
    ) -> Vec<(SocketAddr, SchedulerMsg)> {
        let mut out = Vec::with_capacity(produced.len());
        let mut sender_replies = Vec::new();
        for msg in produced {
            let target_key = match &msg {
                SchedulerMsg::Registered { task_key, .. }
                | SchedulerMsg::LaunchNow { task_key, .. }
                | SchedulerMsg::Hold { task_key, .. } => Some(task_key.clone()),
                // Redirect/RetryAfter answer the rejected sender
                // directly (they are minted in `handle_register`, which
                // bypasses routing — a rejected client has no entry to
                // route through).
                SchedulerMsg::Ack { .. }
                | SchedulerMsg::Error { .. }
                | SchedulerMsg::Redirect { .. }
                | SchedulerMsg::RetryAfter { .. } => None,
            };
            let to = match &target_key {
                Some(k) => {
                    if let SchedulerMsg::LaunchNow { seq, .. } = &msg {
                        if let Some(e) = self.registry.get_mut(k) {
                            e.released.insert(*seq);
                        }
                    }
                    match self.registry.get(k) {
                        Some(e) => e.addr,
                        None => {
                            // Client vanished between minting and routing
                            // — count it instead of losing it silently.
                            self.stats.releases_unroutable += 1;
                            continue;
                        }
                    }
                }
                None => sender_addr,
            };
            if target_key.as_ref() == Some(sender) || target_key.is_none() {
                sender_replies.push(msg.clone());
            }
            out.push((to, msg));
        }
        if let Some(entry) = self.registry.get_mut(sender) {
            if entry.last_msg_seq == msg_seq {
                entry.last_replies = sender_replies;
            }
        }
        out
    }

    /// Append an [`JournalRecord::Apply`] for a message that passed the
    /// dedup guards and is about to mutate state. Returns whether the
    /// caller may proceed: `false` means an injected crash (or a write
    /// failure) killed the daemon and the mutation MUST NOT be applied —
    /// an unjournaled mutation could never be replayed. No-op (true)
    /// while replaying or when the daemon is ephemeral.
    fn wal_apply(&mut self, msg_seq: u64, msg: &ClientMsg, addr: SocketAddr, now: SimTime) -> bool {
        if self.replaying {
            return true;
        }
        let Some(j) = self.journal.as_mut() else {
            return true;
        };
        let rec = JournalRecord::Apply {
            lsn: j.alloc_lsn(),
            now_ns: now.nanos(),
            msg_seq,
            addr,
            msg: msg.clone(),
        };
        match j.append(&rec) {
            Ok(a) if !a.crash_before_apply => true,
            _ => {
                self.crashed = true;
                false
            }
        }
    }

    /// Append an [`JournalRecord::Admit`] for a fresh placement (same
    /// fail-stop contract as [`SchedulerDaemon::wal_apply`]).
    fn wal_admit(&mut self, task_key: &TaskKey, shard: usize, service_id: u64) -> bool {
        if self.replaying {
            return true;
        }
        let Some(j) = self.journal.as_mut() else {
            return true;
        };
        let rec = JournalRecord::Admit {
            lsn: j.alloc_lsn(),
            task_key: task_key.clone(),
            shard,
            service_id,
        };
        match j.append(&rec) {
            Ok(a) if !a.crash_before_apply => true,
            _ => {
                self.crashed = true;
                false
            }
        }
    }

    /// Write a snapshot + truncate the journal when the cadence is due.
    /// Snapshot failure is deliberately non-fatal: the journal simply
    /// keeps growing and the next cadence retries — durability is never
    /// weaker than journal-only.
    fn maybe_snapshot(&mut self, now: SimTime) {
        if self.replaying || self.crashed {
            return;
        }
        if !self.journal.as_ref().is_some_and(Journal::snapshot_due) {
            return;
        }
        let state = self.state_json();
        if let Some(j) = self.journal.as_mut() {
            let _ = j.write_snapshot(&state, now.nanos());
        }
    }

    /// Deterministic JSON image of the daemon's externally observable
    /// state: registry (clients, dedup caches, fleet residency), every
    /// shard (active sets, queues, windows, conservation counters) and
    /// the live profile store. This is both the journal-snapshot body
    /// and the convergence image the recovery property tests compare —
    /// two daemons with equal `state_json` answer every future message
    /// identically. Wire counters ([`DaemonStats`]) are deliberately
    /// per-process and excluded: a restarted daemon legitimately sees
    /// different duplicate/decode counts than one that never died.
    pub fn state_json(&self) -> Json {
        Json::obj()
            .set("registry", self.registry.snapshot_json())
            .set(
                "shards",
                Json::Arr(self.shards.iter().map(Shard::snapshot_json).collect()),
            )
            .set("profiles", self.profiles.to_json())
    }

    /// Restore registry, shards and profiles from a snapshot `state`
    /// document (inverse of [`SchedulerDaemon::state_json`], onto the
    /// freshly constructed daemon in [`SchedulerDaemon::with_journal`]).
    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.registry = Registry::restore_snapshot(
            state.require("registry")?,
            self.cfg.devices,
            self.cfg.capacity,
            self.cfg.policy,
        )?;
        let shards = state.req_arr("shards")?;
        if shards.len() != self.shards.len() {
            return Err(Error::Config(format!(
                "journal snapshot has {} shards but the daemon is configured \
                 for {} devices",
                shards.len(),
                self.shards.len()
            )));
        }
        for (shard, sj) in self.shards.iter_mut().zip(shards) {
            shard.restore_snapshot(sj)?;
        }
        // Epoch precedence: journaled/snapshotted profile epochs must
        // never be regressed by whatever store the daemon booted with
        // (mirrors the refiner's never-regress restart contract).
        self.profiles
            .merge_newer(ProfileStore::from_json(state.require("profiles")?)?);
        Ok(())
    }

    /// Re-run one journaled record through the ordinary message path.
    /// Returns the record's timestamp (for the post-replay time base).
    fn replay_record(&mut self, rec: &JournalRecord) -> Result<u64> {
        match rec {
            JournalRecord::Apply {
                now_ns,
                msg_seq,
                addr,
                msg,
                ..
            } => {
                // Replies went to the wire before the crash (or were
                // lost with it); either way the retry loop re-elicits
                // them, so replay discards its output.
                let _ = self.handle_at(*msg_seq, msg.clone(), *addr, SimTime(*now_ns));
                Ok(*now_ns)
            }
            JournalRecord::Admit {
                task_key,
                shard,
                service_id,
                ..
            } => {
                // Placement convergence check: the replayed Register
                // must have produced the journaled decision.
                let entry = self.registry.get(task_key).ok_or_else(|| {
                    Error::Invariant(format!(
                        "replay divergence: journal admits {:?} but replay did not \
                         register it",
                        task_key.as_str()
                    ))
                })?;
                if entry.shard != *shard || entry.service_id != *service_id {
                    return Err(Error::Invariant(format!(
                        "replay divergence for {:?}: journal admits shard {shard} \
                         service {service_id}, replay placed shard {} service {}",
                        task_key.as_str(),
                        entry.shard,
                        entry.service_id
                    )));
                }
                Ok(0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, Duration, KernelId, Priority, SimTime, TaskId};
    use crate::profile::TaskProfile;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(4), Dim3::x(64))
    }

    fn profiles() -> ProfileStore {
        let mut profiles = ProfileStore::new();
        let mut hi = TaskProfile::new(TaskKey::new("hi"));
        hi.record(&kid("hk"), Duration::from_micros(200), Some(Duration::from_millis(2)));
        hi.finish_run(1);
        profiles.insert(hi);
        let mut lo = TaskProfile::new(TaskKey::new("lo"));
        lo.record(&kid("lk"), Duration::from_micros(400), Some(Duration::from_micros(20)));
        lo.finish_run(1);
        profiles.insert(lo);
        profiles
    }

    fn daemon(devices: usize) -> SchedulerDaemon {
        SchedulerDaemon::new(
            DaemonConfig {
                devices,
                ..Default::default()
            },
            profiles(),
        )
    }

    /// Drive a message with an auto-incrementing per-client counter.
    struct Driver {
        seqs: std::collections::HashMap<TaskKey, u64>,
    }

    impl Driver {
        fn new() -> Driver {
            Driver {
                seqs: std::collections::HashMap::new(),
            }
        }

        fn send(
            &mut self,
            d: &mut SchedulerDaemon,
            msg: ClientMsg,
            from: SocketAddr,
        ) -> Vec<(SocketAddr, SchedulerMsg)> {
            let seq = self.seqs.entry(msg.task_key().clone()).or_insert(0);
            *seq += 1;
            d.handle(*seq, msg, from)
        }
    }

    fn register(key: &str, prio: Priority) -> ClientMsg {
        ClientMsg::Register {
            task_key: TaskKey::new(key),
            priority: prio,
            has_symbols: true,
            model: None,
        }
    }

    fn task_start(key: &str) -> ClientMsg {
        ClientMsg::TaskStart {
            task_key: TaskKey::new(key),
            task_id: TaskId(0),
        }
    }

    fn launch_msg(key: &str, kernel: &str, seq: u32) -> ClientMsg {
        ClientMsg::Launch {
            task_key: TaskKey::new(key),
            task_id: TaskId(0),
            kernel_name: kernel.to_string(),
            grid: Dim3::x(4),
            block: Dim3::x(64),
            seq,
            issued_at: SimTime::ZERO,
        }
    }

    fn completion(key: &str, seq: u32) -> ClientMsg {
        ClientMsg::Completion {
            task_key: TaskKey::new(key),
            task_id: TaskId(0),
            seq,
            exec: Duration::from_micros(200),
            finished_at: SimTime(1),
        }
    }

    #[test]
    fn register_reports_stage() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        let r = drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        assert!(matches!(
            r[0].1,
            SchedulerMsg::Registered { sharing_stage: true, .. }
        ));
        // Unknown service → measurement stage.
        let r = drv.send(&mut d, register("new", Priority::P5), addr(9002));
        assert!(matches!(
            r[0].1,
            SchedulerMsg::Registered { sharing_stage: false, .. }
        ));
        // No symbols → never sharing stage, even with a profile.
        let r = d.handle(
            99,
            ClientMsg::Register {
                task_key: TaskKey::new("hi"),
                priority: Priority::P0,
                has_symbols: false,
                model: None,
            },
            addr(9001),
        );
        assert!(matches!(
            r[0].1,
            SchedulerMsg::Registered { sharing_stage: false, .. }
        ));
    }

    #[test]
    fn priority_hold_window_release_and_stats() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            drv.send(&mut d, register(key, prio), addr(port));
            drv.send(&mut d, task_start(key), addr(port));
        }
        // Holder launch → immediate release.
        let r = drv.send(&mut d, launch_msg("hi", "hk", 0), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
        // Low-priority launch → held.
        let r = drv.send(&mut d, launch_msg("lo", "lk", 0), addr(9002));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
        assert_eq!(d.shard_stats(0).holds, 1);
        // Holder kernel completes → window opens → held launch released
        // to lo's address (plus the Ack to hi).
        let r = drv.send(&mut d, completion("hi", 0), addr(9001));
        let released: Vec<_> = r
            .iter()
            .filter(|(_, m)| matches!(m, SchedulerMsg::LaunchNow { .. }))
            .collect();
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].0, addr(9002));
        assert!(r.iter().any(|(to, m)| matches!(m, SchedulerMsg::Ack { .. }) && *to == addr(9001)));
        assert_eq!(d.shard_stats(0).windows, 1);
        assert_eq!(d.shard_stats(0).releases_filled, 1);
        assert_eq!(d.shard_stats(0).releases_drained, 0);
        assert_eq!(
            d.shard_sizes()[0].launched_kernels,
            0,
            "the completion consumed its lookup entry (map bounded by in-flight kernels)"
        );
        // Next holder launch with the window still open → early stop.
        let r = drv.send(&mut d, launch_msg("hi", "hk", 1), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
        assert_eq!(d.shard_stats(0).early_stops, 1);
    }

    #[test]
    fn task_end_drain_counts_as_drained_not_filled() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            drv.send(&mut d, register(key, prio), addr(port));
            drv.send(&mut d, task_start(key), addr(port));
        }
        drv.send(&mut d, launch_msg("lo", "lk", 3), addr(9002));
        // Holder finishes its task: lo becomes holder, gets released.
        let r = drv.send(
            &mut d,
            ClientMsg::TaskEnd {
                task_key: TaskKey::new("hi"),
                task_id: TaskId(0),
            },
            addr(9001),
        );
        assert!(r
            .iter()
            .any(|(to, m)| matches!(m, SchedulerMsg::LaunchNow { seq: 3, .. }) && *to == addr(9002)));
        let s = d.shard_stats(0);
        assert_eq!(s.releases_drained, 1, "drain released it");
        assert_eq!(s.releases_filled, 0, "no window was involved");
    }

    #[test]
    fn preempted_launch_reparks_without_filling_stats() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            drv.send(&mut d, register(key, prio), addr(port));
            drv.send(&mut d, task_start(key), addr(port));
        }
        // lo parks, hi's completion opens a window and releases it.
        drv.send(&mut d, launch_msg("hi", "hk", 0), addr(9001));
        drv.send(&mut d, launch_msg("lo", "lk", 0), addr(9002));
        drv.send(&mut d, completion("hi", 0), addr(9001));
        let filled_before = d.shard_stats(0).releases_filled;
        assert_eq!(filled_before, 1, "window released the fill");
        // The coordinator preempts lo's in-flight kernel; the client
        // reports the remnant. It must re-park as a Hold, not count as a
        // second release, and the registry must forget the release so a
        // later retransmit of the same seq is not treated as duplicate.
        let r = drv.send(
            &mut d,
            ClientMsg::Preempted {
                task_key: TaskKey::new("lo"),
                task_id: TaskId(0),
                kernel_name: "lk".to_string(),
                grid: Dim3::x(4),
                block: Dim3::x(64),
                seq: 0,
                remaining: Duration::from_micros(120),
            },
            addr(9002),
        );
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }), "remnant re-parked");
        assert!(r.iter().any(|(_, m)| matches!(m, SchedulerMsg::Ack { .. })));
        let s = d.shard_stats(0);
        assert_eq!(s.reparked, 1);
        assert_eq!(s.releases_filled, filled_before, "repark is not a release");
        assert_eq!(d.shard_sizes()[0].queued, 1, "remnant waits in the queues");
    }

    #[test]
    fn unregistered_sender_gets_error_not_queue_entry() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        drv.send(&mut d, task_start("hi"), addr(9001));
        let r = drv.send(&mut d, launch_msg("ghost", "gk", 0), addr(9009));
        assert!(matches!(r[0].1, SchedulerMsg::Error { .. }));
        assert_eq!(d.stats().unknown_service, 1);
        assert_eq!(d.shard_sizes()[0].queued, 0, "hostile traffic parks nothing");
    }

    #[test]
    fn duplicate_task_start_is_idempotent() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        drv.send(&mut d, task_start("hi"), addr(9001));
        // Same msg_seq (true retransmit): dedup layer absorbs it.
        let r = d.handle(2, task_start("hi"), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::Ack { .. }), "cached ack re-sent");
        assert_eq!(d.stats().duplicates, 1);
        // New msg_seq but semantically duplicate: shard guard absorbs it.
        drv.send(&mut d, task_start("hi"), addr(9001));
        assert_eq!(d.shard_stats(0).duplicate_task_starts, 1);
        assert_eq!(d.shard_sizes()[0].active, 1, "active set never double-pushed");
    }

    #[test]
    fn duplicate_launch_retransmit_does_not_double_park() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            drv.send(&mut d, register(key, prio), addr(port));
            drv.send(&mut d, task_start(key), addr(port));
        }
        let r = drv.send(&mut d, launch_msg("lo", "lk", 0), addr(9002));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
        // Retransmit (same msg_seq = 3): cached Hold resent, not re-parked.
        let r = d.handle(3, launch_msg("lo", "lk", 0), addr(9002));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
        assert_eq!(d.shard_sizes()[0].queued, 1, "parked exactly once");
        assert_eq!(d.shard_stats(0).launches, 1, "side effects not re-executed");
        // Duplicate holder Launch: immediate release replayed, stats flat.
        let r = drv.send(&mut d, launch_msg("hi", "hk", 0), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
        let immediate_before = d.shard_stats(0).releases_immediate;
        let r = d.handle(3, launch_msg("hi", "hk", 0), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
        assert_eq!(d.shard_stats(0).releases_immediate, immediate_before);
        assert_eq!(d.shard_sizes()[0].launched_kernels, 1);
    }

    #[test]
    fn holder_disconnect_mid_window_promotes_and_purges() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            drv.send(&mut d, register(key, prio), addr(port));
            drv.send(&mut d, task_start(key), addr(port));
        }
        // hi launches seq 0; its completion opens a 2ms window. lo then
        // parks a launch (released through the window if the wall clock
        // cooperates, drained on promotion otherwise — both paths are
        // asserted by the conservation check below).
        drv.send(&mut d, launch_msg("hi", "hk", 0), addr(9001));
        drv.send(&mut d, completion("hi", 0), addr(9001));
        assert!(d.shard(0).window_open(), "window open mid-scenario");
        drv.send(&mut d, launch_msg("lo", "lk", 7), addr(9002));
        let r = drv.send(
            &mut d,
            ClientMsg::Disconnect {
                task_key: TaskKey::new("hi"),
            },
            addr(9001),
        );
        // hi's window is gone, lo was promoted and its parked launch (if
        // the window had not already released it) drained.
        assert!(!d.shard(0).window_open(), "stale window cleared");
        assert_eq!(d.clients(), 1);
        let sizes = d.shard_sizes()[0];
        assert_eq!(sizes.queued, 0, "no orphaned launches");
        assert_eq!(
            sizes.launched_kernels, 0,
            "holder's completion-lookup entries purged"
        );
        // Every parked lo launch was released one way or the other.
        let s = d.shard_stats(0);
        assert_eq!(s.holds, s.releases_filled + s.releases_drained);
        assert!(r.iter().any(|(_, m)| matches!(m, SchedulerMsg::Ack { .. })));
    }

    #[test]
    fn orphaned_held_launches_are_purged_on_disconnect() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            drv.send(&mut d, register(key, prio), addr(port));
            drv.send(&mut d, task_start(key), addr(port));
        }
        for seq in 0..4 {
            drv.send(&mut d, launch_msg("lo", "lk", seq), addr(9002));
        }
        assert_eq!(d.shard_sizes()[0].queued, 4);
        // lo leaves without waiting: its parked launches must not sit in
        // the queues forever.
        drv.send(
            &mut d,
            ClientMsg::Disconnect {
                task_key: TaskKey::new("lo"),
            },
            addr(9002),
        );
        assert_eq!(d.shard_sizes()[0].queued, 0);
        assert_eq!(d.shard_stats(0).purged_launches, 4);
    }

    #[test]
    fn launched_kernels_purged_on_task_end() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        drv.send(&mut d, task_start("hi"), addr(9001));
        for seq in 0..16 {
            drv.send(&mut d, launch_msg("hi", "hk", seq), addr(9001));
        }
        assert_eq!(d.shard_sizes()[0].launched_kernels, 16);
        drv.send(
            &mut d,
            ClientMsg::TaskEnd {
                task_key: TaskKey::new("hi"),
                task_id: TaskId(0),
            },
            addr(9001),
        );
        assert_eq!(
            d.shard_sizes()[0].launched_kernels,
            0,
            "the per-(service,seq) map must not grow without bound"
        );
    }

    #[test]
    fn release_query_answers_from_record_queue_or_error() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            drv.send(&mut d, register(key, prio), addr(port));
            drv.send(&mut d, task_start(key), addr(port));
        }
        drv.send(&mut d, launch_msg("lo", "lk", 0), addr(9002));
        // Still parked → Hold.
        let r = drv.send(
            &mut d,
            ClientMsg::ReleaseQuery {
                task_key: TaskKey::new("lo"),
                seq: 0,
            },
            addr(9002),
        );
        assert!(matches!(r[0].1, SchedulerMsg::Hold { seq: 0, .. }));
        // Window releases it → LaunchNow replayed from the record.
        drv.send(&mut d, launch_msg("hi", "hk", 0), addr(9001));
        drv.send(&mut d, completion("hi", 0), addr(9001));
        let r = drv.send(
            &mut d,
            ClientMsg::ReleaseQuery {
                task_key: TaskKey::new("lo"),
                seq: 0,
            },
            addr(9002),
        );
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { seq: 0, .. }));
        // Never-held seq → Error.
        let r = drv.send(
            &mut d,
            ClientMsg::ReleaseQuery {
                task_key: TaskKey::new("lo"),
                seq: 55,
            },
            addr(9002),
        );
        assert!(matches!(r[0].1, SchedulerMsg::Error { .. }));
    }

    /// The `--devices 2` acceptance shape: two high/low pairs land on
    /// different devices and fill independently — two concurrent windows
    /// observable in stats, one fill each, no cross-device interference.
    #[test]
    fn two_devices_fill_independently() {
        let mut profiles = ProfileStore::new();
        for key in ["hi1", "hi2"] {
            let mut p = TaskProfile::new(TaskKey::new(key));
            p.record(&kid("hk"), Duration::from_micros(200), Some(Duration::from_millis(2)));
            p.finish_run(1);
            profiles.insert(p);
        }
        for key in ["lo1", "lo2"] {
            let mut p = TaskProfile::new(TaskKey::new(key));
            p.record(&kid("lk"), Duration::from_micros(400), Some(Duration::from_micros(20)));
            p.finish_run(1);
            profiles.insert(p);
        }
        let mut d = SchedulerDaemon::new(
            DaemonConfig {
                devices: 2,
                capacity: 2,
                ..Default::default()
            },
            profiles,
        );
        let mut drv = Driver::new();
        // LeastLoaded with equal demands alternates devices: hi1→0,
        // hi2→1, lo1→0, lo2→1.
        for (i, (key, prio)) in [
            ("hi1", Priority::P0),
            ("hi2", Priority::P0),
            ("lo1", Priority::P5),
            ("lo2", Priority::P5),
        ]
        .into_iter()
        .enumerate()
        {
            drv.send(&mut d, register(key, prio), addr(9001 + i as u16));
            drv.send(&mut d, task_start(key), addr(9001 + i as u16));
        }
        assert_eq!(d.shard_of(&TaskKey::new("hi1")), Some(0));
        assert_eq!(d.shard_of(&TaskKey::new("hi2")), Some(1));
        assert_eq!(d.shard_of(&TaskKey::new("lo1")), Some(0));
        assert_eq!(d.shard_of(&TaskKey::new("lo2")), Some(1));
        // Holders launch immediately; each device's low service parks.
        drv.send(&mut d, launch_msg("hi1", "hk", 0), addr(9001));
        drv.send(&mut d, launch_msg("hi2", "hk", 0), addr(9002));
        let r = drv.send(&mut d, launch_msg("lo1", "lk", 0), addr(9003));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
        let r = drv.send(&mut d, launch_msg("lo2", "lk", 0), addr(9004));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
        // Both holders complete → two windows open concurrently, each
        // filling its own device's parked launch.
        let r = drv.send(&mut d, completion("hi1", 0), addr(9001));
        assert!(r
            .iter()
            .any(|(to, m)| matches!(m, SchedulerMsg::LaunchNow { .. }) && *to == addr(9003)));
        let r = drv.send(&mut d, completion("hi2", 0), addr(9002));
        assert!(r
            .iter()
            .any(|(to, m)| matches!(m, SchedulerMsg::LaunchNow { .. }) && *to == addr(9004)));
        assert_eq!(d.open_windows(), 2, "two concurrent windows, one per device");
        for device in [0, 1] {
            let s = d.shard_stats(device);
            assert_eq!(s.windows, 1);
            assert_eq!(s.holds, 1);
            assert_eq!(s.releases_filled, 1);
        }
    }

    /// A delayed duplicate of an old Register (same address, old
    /// msg_seq) must not rewind the dedup baseline or wipe session
    /// state; a genuinely restarted client (new address, colliding
    /// msg_seq) must be processed.
    #[test]
    fn stale_mid_session_register_duplicate_is_dropped() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001)); // msg_seq 1
        drv.send(&mut d, task_start("hi"), addr(9001)); // msg_seq 2
        drv.send(&mut d, launch_msg("hi", "hk", 0), addr(9001)); // msg_seq 3
        let r = d.handle(1, register("hi", Priority::P0), addr(9001));
        assert!(r.is_empty(), "stale Register straggler dropped");
        assert_eq!(d.stats().duplicates, 1);
        // Dedup baseline intact: the Launch retransmit is still replayed
        // from cache, not re-executed.
        let r = d.handle(3, launch_msg("hi", "hk", 0), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
        assert_eq!(d.shard_stats(0).launches, 1);
        // Restarted client, new socket, colliding initial msg_seq →
        // processed and answered at the NEW address.
        let r = d.handle(1, register("hi", Priority::P0), addr(9005));
        assert!(matches!(r[0].1, SchedulerMsg::Registered { .. }));
        assert_eq!(r[0].0, addr(9005));
    }

    /// Interference learning end to end (ADR-006): wire completions
    /// whose exec dilated past the profiled SK flow shard →
    /// `take_dilations` → `Registry::observe_interference`, charging the
    /// co-resident on the victim's shard.
    #[test]
    fn completion_dilation_reaches_the_interference_model() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        drv.send(
            &mut d,
            ClientMsg::Register {
                task_key: TaskKey::new("hi"),
                priority: Priority::P0,
                has_symbols: true,
                model: Some("keypointrcnn_resnet50_fpn".into()),
            },
            addr(9001),
        );
        drv.send(
            &mut d,
            ClientMsg::Register {
                task_key: TaskKey::new("lo"),
                priority: Priority::P6,
                has_symbols: true,
                model: Some("googlenet".into()),
            },
            addr(9002),
        );
        drv.send(&mut d, task_start("hi"), addr(9001));
        // Profiled SK(hk) = 200 µs; observed exec = 600 µs → dilation 3×.
        for seq in 0..8 {
            drv.send(&mut d, launch_msg("hi", "hk", seq), addr(9001));
            drv.send(
                &mut d,
                ClientMsg::Completion {
                    task_key: TaskKey::new("hi"),
                    task_id: TaskId(0),
                    seq,
                    exec: Duration::from_micros(600),
                    finished_at: SimTime(1),
                },
                addr(9001),
            );
        }
        let model = d.registry().interference();
        assert_eq!(model.observations(), 8, "one sample per completion");
        let (dilation, samples) = model
            .learned(
                crate::workload::ModelKind::KeypointRcnnResnet50Fpn,
                crate::workload::ModelKind::Googlenet,
            )
            .expect("the idle co-resident is the only aggressor candidate");
        assert_eq!(samples, 8);
        assert!(
            dilation > 2.5,
            "EWMA should sit near the observed 3x, got {dilation}"
        );
    }

    /// The per-shard refiner end to end: wire completions whose exec
    /// times drifted far from the offline SK make the shard republish a
    /// refined profile; the daemon installs it over its store, persists
    /// it, and a restarted daemon resolves the *identical*
    /// `ResolvedProfile` from the saved file (the restart contract).
    #[test]
    fn shard_refiner_republishes_and_survives_restart() {
        let mut cfg = DaemonConfig::default();
        cfg.online.enabled = true;
        let mut d = SchedulerDaemon::new(cfg, profiles());
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        drv.send(&mut d, task_start("hi"), addr(9001));
        // Profiled SK(hk) = 200 µs; observed exec = 600 µs: drift.
        for seq in 0..16 {
            drv.send(&mut d, launch_msg("hi", "hk", seq), addr(9001));
            drv.send(
                &mut d,
                ClientMsg::Completion {
                    task_key: TaskKey::new("hi"),
                    task_id: TaskId(0),
                    seq,
                    exec: Duration::from_micros(600),
                    finished_at: SimTime(1),
                },
                addr(9001),
            );
        }
        assert!(
            d.stats().profiles_refined >= 1,
            "exec drift must republish a refined profile"
        );
        let refined = d.profiles().get(&TaskKey::new("hi")).unwrap();
        assert_eq!(refined.origin, crate::profile::ProfileOrigin::Refined);
        assert!(refined.epoch >= 1);
        let sk = refined.sk(&kid("hk")).unwrap();
        assert!(
            sk > Duration::from_micros(450),
            "refined SK {sk} did not move toward the observed 600 µs"
        );

        // Persist → "restart" → identical ResolvedProfile.
        let dir = std::env::temp_dir().join(format!("fikit-daemon-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");
        d.save_profiles(&path).unwrap();
        let reloaded_store = ProfileStore::load(&path).unwrap();
        let restarted = SchedulerDaemon::new(DaemonConfig::default(), reloaded_store);
        let persisted_epoch = restarted.profiles().get(&TaskKey::new("hi")).unwrap().epoch;

        // Epochs never regress across a restart: a refining restarted
        // daemon publishes *past* the persisted epoch, not from 1.
        let mut cfg2 = DaemonConfig::default();
        cfg2.online.enabled = true;
        let mut d2 = SchedulerDaemon::new(cfg2, ProfileStore::load(&path).unwrap());
        let mut drv2 = Driver::new();
        drv2.send(&mut d2, register("hi", Priority::P0), addr(9011));
        drv2.send(&mut d2, task_start("hi"), addr(9011));
        for seq in 0..16 {
            drv2.send(&mut d2, launch_msg("hi", "hk", seq), addr(9011));
            drv2.send(
                &mut d2,
                ClientMsg::Completion {
                    task_key: TaskKey::new("hi"),
                    task_id: TaskId(0),
                    seq,
                    exec: Duration::from_millis(2),
                    finished_at: SimTime(1),
                },
                addr(9011),
            );
        }
        let re_refined = d2.profiles().get(&TaskKey::new("hi")).unwrap();
        assert!(
            re_refined.epoch > persisted_epoch,
            "epoch regressed across restart: {} after, {} persisted",
            re_refined.epoch,
            persisted_epoch
        );
        std::fs::remove_dir_all(&dir).ok();

        let before = d.profiles().get(&TaskKey::new("hi")).unwrap();
        let after = restarted.profiles().get(&TaskKey::new("hi")).unwrap();
        assert_eq!(after.epoch, before.epoch);
        assert_eq!(after.origin, before.origin);
        let mut i1 = crate::core::Interner::new();
        let rp1 = crate::profile::ResolvedProfile::resolve(before, &mut i1);
        let mut i2 = crate::core::Interner::new();
        let rp2 = crate::profile::ResolvedProfile::resolve(after, &mut i2);
        assert_eq!(i1.kernel_count(), i2.kernel_count());
        let h1 = i1.kernel_handle(&kid("hk")).unwrap();
        let h2 = i2.kernel_handle(&kid("hk")).unwrap();
        assert_eq!(h1, h2, "handles stable across the restart");
        assert_eq!(rp1.sk(h1), rp2.sk(h2));
        assert_eq!(rp1.sg(h1), rp2.sg(h2));

        // The refiner map is bounded by connected services.
        assert_eq!(d.shard_sizes()[0].refiner_tasks, 1);
        drv.send(
            &mut d,
            ClientMsg::Disconnect {
                task_key: TaskKey::new("hi"),
            },
            addr(9001),
        );
        assert_eq!(d.shard_sizes()[0].refiner_tasks, 0);
    }

    /// With refinement off (the default) the wire path never tracks or
    /// republishes anything — frozen offline profiles, as before.
    #[test]
    fn refinement_off_by_default_keeps_profiles_frozen() {
        let mut d = daemon(1);
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        drv.send(&mut d, task_start("hi"), addr(9001));
        for seq in 0..16 {
            drv.send(&mut d, launch_msg("hi", "hk", seq), addr(9001));
            drv.send(&mut d, completion("hi", seq), addr(9001));
        }
        assert_eq!(d.stats().profiles_refined, 0);
        assert_eq!(d.shard_sizes()[0].refiner_tasks, 0);
        let p = d.profiles().get(&TaskKey::new("hi")).unwrap();
        assert_eq!(p.origin, crate::profile::ProfileOrigin::Measured);
        assert_eq!(p.epoch, 0);
    }

    /// Journal round trip (ADR-004): a journaled daemon driven through a
    /// full register→hold→window→fill scenario, restarted cold from its
    /// journal directory, reconstructs byte-identical observable state —
    /// including the dedup cache, so a retransmit that straddles the
    /// restart replays the cached reply instead of re-executing.
    #[test]
    fn journal_round_trip_restores_state_and_dedup() {
        let dir = std::env::temp_dir().join(format!("fikit-wal-unit-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let jcfg = JournalConfig {
            fsync: false,
            snapshot_every: 0,
        };
        let mut d = SchedulerDaemon::with_journal(
            DaemonConfig::default(),
            profiles(),
            &dir,
            jcfg.clone(),
        )
        .unwrap();
        let t = SimTime;
        d.handle_at(1, register("hi", Priority::P0), addr(9001), t(1_000));
        d.handle_at(2, task_start("hi"), addr(9001), t(2_000));
        d.handle_at(1, register("lo", Priority::P4), addr(9002), t(3_000));
        d.handle_at(2, task_start("lo"), addr(9002), t(4_000));
        d.handle_at(3, launch_msg("hi", "hk", 0), addr(9001), t(5_000));
        let r = d.handle_at(3, launch_msg("lo", "lk", 0), addr(9002), t(6_000));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
        // Window opens mid-scenario and fills lo's parked launch — the
        // restart happens with a still-open window and released seqs.
        let r = d.handle_at(4, completion("hi", 0), addr(9001), t(7_000));
        assert!(r
            .iter()
            .any(|(to, m)| matches!(m, SchedulerMsg::LaunchNow { .. }) && *to == addr(9002)));
        assert!(d.shard(0).window_open());
        let reference = d.state_json();
        drop(d);

        let mut d2 =
            SchedulerDaemon::with_journal(DaemonConfig::default(), profiles(), &dir, jcfg)
                .unwrap();
        assert_eq!(d2.state_json(), reference, "replay reconstructs the image");
        assert!(d2.shard(0).window_open(), "open fill window survived");
        assert_eq!(d2.clients(), 2, "no admitted live session was lost");
        // Dedup state survived: hi's msg_seq 4 retransmit is absorbed.
        let launches = d2.shard_stats(0).launches;
        let r = d2.handle(4, completion("hi", 0), addr(9001));
        assert!(r.iter().any(|(_, m)| matches!(m, SchedulerMsg::Ack { .. })));
        assert_eq!(d2.stats().duplicates, 1, "retransmit hit the rebuilt cache");
        assert_eq!(d2.shard_stats(0).launches, launches, "no duplicate side effects");
        // And fresh traffic still works at a time past every replayed one.
        let r = d2.handle(5, launch_msg("hi", "hk", 1), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// With no live peer, an over-capacity register is answered with an
    /// explicit `RetryAfter` shed (reason + back-off hint) — never a
    /// silent timeout, never an unbounded queue.
    #[test]
    fn capacity_rejection_sheds_explicitly_without_peers() {
        let mut d = SchedulerDaemon::new(
            DaemonConfig {
                devices: 1,
                capacity: 1,
                ..Default::default()
            },
            profiles(),
        );
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        let r = drv.send(&mut d, register("lo", Priority::P4), addr(9002));
        let SchedulerMsg::RetryAfter { ms, ref reason, .. } = r[0].1 else {
            panic!("expected RetryAfter shed, got {:?}", r[0].1);
        };
        assert_eq!(ms, d.cfg.fleet.retry_after_ms);
        assert!(reason.contains("capacity"), "shed carries a reason: {reason}");
        assert_eq!(d.stats().rejected_capacity, 1);
        assert_eq!(d.stats().sheds, 1);
        assert_eq!(d.stats().redirects, 0);
        assert_eq!(d.clients(), 1);
    }

    /// With a live, non-draining peer advertising free slots, the same
    /// rejection becomes a `Redirect{node}` — cross-node admission.
    #[test]
    fn capacity_rejection_redirects_to_live_peer() {
        let mut d = SchedulerDaemon::new(
            DaemonConfig {
                devices: 1,
                capacity: 1,
                node: Some("n0".into()),
                ..Default::default()
            },
            profiles(),
        );
        // Fold a peer beacon in as if it had just arrived on the wire.
        let beacon = PeerMsg::Beacon {
            node: "n1".into(),
            seq: 1,
            sent_at_ns: 0,
            devices: 1,
            capacity: 4,
            residents: 1,
            draining: false,
        };
        let now = SimTime(d.base_ns + d.epoch.elapsed().as_nanos() as u64);
        d.observe_beacon_at(&beacon, now);
        assert_eq!(d.stats().beacons_received, 1);
        let mut drv = Driver::new();
        drv.send(&mut d, register("hi", Priority::P0), addr(9001));
        let r = drv.send(&mut d, register("lo", Priority::P4), addr(9002));
        let SchedulerMsg::Redirect { ref node, .. } = r[0].1 else {
            panic!("expected Redirect, got {:?}", r[0].1);
        };
        assert_eq!(node, "n1");
        assert_eq!(d.stats().rejected_capacity, 1);
        assert_eq!(d.stats().redirects, 1);
        assert_eq!(d.stats().sheds, 0);
        // A stale replay of the same beacon is counted, not folded.
        d.observe_beacon_at(&beacon, now);
        assert_eq!(d.stats().beacons_stale, 1);
    }
}
