//! Write-ahead session journal: durable daemon sessions across restarts
//! (DESIGN.md §Daemon, ADR-004).
//!
//! The daemon's registry, per-shard capacity accounting and open fill
//! windows used to die with the process; a bounce violated exactly the
//! QoS the scheduler exists to protect. This module makes every
//! session-lifecycle mutation durable *before* it is acknowledged:
//!
//! * [`JournalRecord`] — one length-prefixed, CRC-32-checksummed record
//!   per applied mutation: the decoded wire message plus the timestamp
//!   it was processed at (`Apply`), and the placement decision of every
//!   fresh admission (`Admit`). Replaying the records through the same
//!   deterministic `handle` path reconstructs the registry, the shards'
//!   queues/windows/maps, and the per-client retransmit-dedup state.
//! * [`Journal`] — append-only file plus periodic snapshot + truncate
//!   (the snapshot reuses the atomic tmp-write + rename idiom of
//!   `profile/store.rs`), so the journal stays bounded. Records carry a
//!   monotone LSN; a crash between snapshot rename and journal truncate
//!   merely leaves already-snapshotted records behind, which replay
//!   skips by LSN.
//! * [`FaultPlan`] — scripted crash injection for the recovery tests:
//!   die after record N, mid-append (torn tail), or between append and
//!   apply. The recovery property suite (`tests/daemon_recovery.rs`)
//!   drives every crash point and asserts the restarted daemon
//!   converges to the uncrashed daemon's state.
//!
//! Torn-tail semantics (the crash-consistency contract): an append is a
//! single sequential write, so process death leaves at most one
//! *incomplete* frame at the end of the file — that prefix is truncated
//! and the longest valid prefix replayed. A *complete* frame whose
//! checksum or payload fails to decode is NOT a torn tail; it is
//! mid-file corruption, and recovery fails loudly rather than silently
//! replaying past it (ADR-004 §Recovery).

use crate::core::{Error, Result, TaskKey};
use crate::hook::protocol::ClientMsg;
use crate::util::json::Json;
use std::fs;
use std::io::{Seek, SeekFrom, Write};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};

/// Journal file name inside the `--journal` directory.
pub const JOURNAL_FILE: &str = "journal.waj";
/// Snapshot file name inside the `--journal` directory.
pub const SNAPSHOT_FILE: &str = "snapshot.json";
/// Snapshot document format version.
pub const SNAPSHOT_VERSION: u64 = 1;

/// Sanity cap on one record's payload. A length prefix beyond this is
/// certainly corruption (session-lifecycle records are < 1 KiB), and
/// failing loudly beats mis-classifying a corrupted length as a torn
/// tail and silently dropping everything after it.
pub const MAX_RECORD_LEN: usize = 1 << 20;

// ---------------------------------------------------------------------
// CRC-32 (IEEE, reflected) — the checksum guarding each record.
// ---------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32/IEEE of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------

/// One durable session-lifecycle record.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalRecord {
    /// A wire message the daemon is about to apply (it passed decode and
    /// the retransmit-dedup guards). Carries everything replay needs to
    /// re-run the exact same deterministic `handle` path: the envelope
    /// sequence, the sender address (rebuilds reply routing and dedup
    /// state) and the timestamp the daemon processed it at (fill-window
    /// arithmetic depends on `now`).
    Apply {
        lsn: u64,
        now_ns: u64,
        msg_seq: u64,
        addr: SocketAddr,
        msg: ClientMsg,
    },
    /// The placement decision of a fresh admission, appended after the
    /// registry placed the service. Replay recomputes placement
    /// deterministically from the `Apply` stream; this record lets it
    /// *verify* convergence and fail loudly on divergence instead of
    /// silently rebuilding a different fleet.
    Admit {
        lsn: u64,
        task_key: TaskKey,
        shard: usize,
        service_id: u64,
    },
}

impl JournalRecord {
    /// Log sequence number — monotone across snapshots, so replay can
    /// skip records already covered by a snapshot.
    pub fn lsn(&self) -> u64 {
        match self {
            JournalRecord::Apply { lsn, .. } | JournalRecord::Admit { lsn, .. } => *lsn,
        }
    }

    fn to_json(&self) -> Json {
        match self {
            JournalRecord::Apply {
                lsn,
                now_ns,
                msg_seq,
                addr,
                msg,
            } => Json::obj()
                .set("kind", "apply")
                .set("lsn", *lsn)
                .set("now_ns", *now_ns)
                .set("msg_seq", *msg_seq)
                .set("addr", addr.to_string().as_str())
                .set("msg", msg.to_json()),
            JournalRecord::Admit {
                lsn,
                task_key,
                shard,
                service_id,
            } => Json::obj()
                .set("kind", "admit")
                .set("lsn", *lsn)
                .set("task_key", task_key.as_str())
                .set("shard", *shard)
                .set("service_id", *service_id),
        }
    }

    fn from_json(v: &Json) -> Result<JournalRecord> {
        match v.req_str("kind")? {
            "apply" => Ok(JournalRecord::Apply {
                lsn: v.req_u64("lsn")?,
                now_ns: v.req_u64("now_ns")?,
                msg_seq: v.req_u64("msg_seq")?,
                addr: v
                    .req_str("addr")?
                    .parse()
                    .map_err(|_| Error::Protocol("journal record has a bad addr".into()))?,
                msg: ClientMsg::from_json(v.require("msg")?)?,
            }),
            "admit" => Ok(JournalRecord::Admit {
                lsn: v.req_u64("lsn")?,
                task_key: TaskKey::new(v.req_str("task_key")?),
                shard: v.req_u64("shard")? as usize,
                service_id: v.req_u64("service_id")?,
            }),
            other => Err(Error::Protocol(format!(
                "unknown journal record kind {other:?}"
            ))),
        }
    }

    /// Frame: `[payload len: u32 LE][crc32(payload): u32 LE][payload]`.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.to_json().encode().into_bytes();
        let mut out = Vec::with_capacity(payload.len() + 8);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }
}

/// Result of scanning a journal file's bytes.
#[derive(Debug)]
pub struct ScanOutcome {
    /// Every complete, checksum-valid record, in append order.
    pub records: Vec<JournalRecord>,
    /// Byte length of the valid prefix. Anything past it is a torn
    /// (incomplete) final record and must be truncated before the file
    /// is appended to again.
    pub valid_len: u64,
    /// Whether a torn tail was cut off.
    pub torn: bool,
}

/// Decode a journal byte stream into the longest valid prefix of
/// records. An incomplete frame at the end is a torn tail (truncated by
/// the crash-consistency argument in the module docs); a *complete*
/// frame with a bad checksum, a non-JSON payload or an insane length
/// prefix is corruption and fails loudly.
pub fn scan(bytes: &[u8]) -> Result<ScanOutcome> {
    let mut records = Vec::new();
    let mut off = 0usize;
    loop {
        let rest = &bytes[off..];
        if rest.is_empty() {
            return Ok(ScanOutcome {
                records,
                valid_len: off as u64,
                torn: false,
            });
        }
        if rest.len() < 8 {
            return Ok(ScanOutcome {
                records,
                valid_len: off as u64,
                torn: true,
            });
        }
        let len = u32::from_le_bytes(rest[0..4].try_into().expect("4 bytes")) as usize;
        if len > MAX_RECORD_LEN {
            return Err(Error::Invariant(format!(
                "journal record at byte {off} claims {len} bytes (cap {MAX_RECORD_LEN}): \
                 corrupted length prefix"
            )));
        }
        if rest.len() < 8 + len {
            return Ok(ScanOutcome {
                records,
                valid_len: off as u64,
                torn: true,
            });
        }
        let want = u32::from_le_bytes(rest[4..8].try_into().expect("4 bytes"));
        let payload = &rest[8..8 + len];
        if crc32(payload) != want {
            return Err(Error::Invariant(format!(
                "journal checksum mismatch at byte {off} (record {}): refusing to \
                 replay past corruption",
                records.len()
            )));
        }
        let text = std::str::from_utf8(payload).map_err(|_| {
            Error::Invariant(format!("journal record at byte {off} is not UTF-8"))
        })?;
        records.push(JournalRecord::from_json(&Json::parse(text)?)?);
        off += 8 + len;
    }
}

// ---------------------------------------------------------------------
// Crash injection
// ---------------------------------------------------------------------

/// Where a scripted crash kills the daemon (`tests/daemon_recovery.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPoint {
    /// Die after record `n` was appended, applied AND its replies routed
    /// — a clean cut. Enforced by the test harness (it stops feeding),
    /// not by the journal.
    AfterProcess(u64),
    /// Die after append `n` is fully durable but before the mutation is
    /// applied. Replay applies it; the client's retransmit is then
    /// absorbed by the rebuilt dedup state.
    AfterAppend(u64),
    /// Die mid-way through append `n`, leaving only the first `keep`
    /// bytes of the frame on disk — the torn-tail case. Recovery
    /// truncates the partial frame; the client's retransmit re-applies
    /// the lost mutation.
    MidAppend { record: u64, keep: usize },
}

/// A scripted crash plan, armed on a [`Journal`] by the test harness.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    pub point: CrashPoint,
}

impl FaultPlan {
    pub fn new(point: CrashPoint) -> FaultPlan {
        FaultPlan { point }
    }
}

/// Outcome of one append.
#[derive(Debug, Clone, Copy)]
pub struct Appended {
    /// An armed [`FaultPlan`] tripped: the daemon must treat itself as
    /// dead and NOT apply the mutation this record announced.
    pub crash_before_apply: bool,
}

// ---------------------------------------------------------------------
// The journal
// ---------------------------------------------------------------------

/// Append/snapshot policy.
#[derive(Debug, Clone)]
pub struct JournalConfig {
    /// `sync_data` after every append. Off by default: the journal then
    /// survives process death (the kernel holds the pages) but not
    /// machine power loss — the right trade for a scheduler daemon whose
    /// sessions are also bounded by client retry windows.
    pub fsync: bool,
    /// Write a snapshot and truncate the journal after this many
    /// appended records (`0` = never snapshot).
    pub snapshot_every: u64,
}

impl Default for JournalConfig {
    fn default() -> JournalConfig {
        JournalConfig {
            fsync: false,
            snapshot_every: 1024,
        }
    }
}

/// What [`Journal::open`] recovered from the directory.
pub struct Recovered {
    pub journal: Journal,
    /// The snapshot document, if one was ever written:
    /// `{version, last_lsn, now_ns, state}`.
    pub snapshot: Option<Json>,
    /// Journal records newer than the snapshot, in append order.
    pub tail: Vec<JournalRecord>,
    /// Whether a torn final record was truncated during recovery.
    pub torn_tail: bool,
}

/// The write-ahead session journal: an append-only record file plus a
/// periodically rewritten snapshot, both inside one directory.
pub struct Journal {
    dir: PathBuf,
    file: fs::File,
    cfg: JournalConfig,
    next_lsn: u64,
    last_lsn: u64,
    since_snapshot: u64,
    /// Appends performed by THIS process incarnation (the fault-plan
    /// counter — crash points are scripted per incarnation).
    appends: u64,
    fault: Option<FaultPlan>,
    tripped: bool,
}

impl Journal {
    /// Open (creating if needed) the journal directory, recover the
    /// snapshot + valid record tail, and truncate any torn final record
    /// so future appends extend a valid prefix.
    pub fn open(dir: impl AsRef<Path>, cfg: JournalConfig) -> Result<Recovered> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        let snap_path = dir.join(SNAPSHOT_FILE);
        let snapshot = match fs::read_to_string(&snap_path) {
            Ok(text) => {
                let doc = Json::parse(&text)?;
                let version = doc.req_u64("version")?;
                if version != SNAPSHOT_VERSION {
                    return Err(Error::Config(format!(
                        "journal snapshot version {version} unsupported \
                         (expected {SNAPSHOT_VERSION})"
                    )));
                }
                Some(doc)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(e.into()),
        };
        let snap_lsn = match &snapshot {
            Some(doc) => doc.req_u64("last_lsn")?,
            None => 0,
        };
        let jpath = dir.join(JOURNAL_FILE);
        let bytes = match fs::read(&jpath) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        let outcome = scan(&bytes)?;
        let mut file = fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&jpath)?;
        file.set_len(outcome.valid_len)?;
        file.seek(SeekFrom::End(0))?;
        let last_lsn = outcome
            .records
            .last()
            .map(JournalRecord::lsn)
            .unwrap_or(0)
            .max(snap_lsn);
        // A crash between snapshot rename and journal truncate leaves
        // already-covered records in the file; skip them by LSN.
        let tail: Vec<JournalRecord> = outcome
            .records
            .into_iter()
            .filter(|r| r.lsn() > snap_lsn)
            .collect();
        let since_snapshot = tail.len() as u64;
        Ok(Recovered {
            journal: Journal {
                dir,
                file,
                cfg,
                next_lsn: last_lsn + 1,
                last_lsn,
                since_snapshot,
                appends: 0,
                fault: None,
                tripped: false,
            },
            snapshot,
            tail,
            torn_tail: outcome.torn,
        })
    }

    /// Allocate the next record's LSN.
    pub fn alloc_lsn(&mut self) -> u64 {
        let lsn = self.next_lsn;
        self.next_lsn += 1;
        self.last_lsn = lsn;
        lsn
    }

    /// Highest LSN allocated so far.
    pub fn last_lsn(&self) -> u64 {
        self.last_lsn
    }

    /// Appends performed by this process incarnation.
    pub fn appends(&self) -> u64 {
        self.appends
    }

    /// Arm a scripted crash (recovery tests only).
    pub fn arm(&mut self, plan: FaultPlan) {
        self.fault = Some(plan);
    }

    /// Whether an armed crash plan has tripped.
    pub fn tripped(&self) -> bool {
        self.tripped
    }

    /// Append one record. Returns whether an injected crash tripped —
    /// in which case the caller must NOT apply the mutation (the
    /// "process" is dead from this point on; further appends no-op).
    pub fn append(&mut self, rec: &JournalRecord) -> Result<Appended> {
        if self.tripped {
            return Ok(Appended {
                crash_before_apply: true,
            });
        }
        self.appends += 1;
        let frame = rec.encode();
        let (write_len, trip) = match self.fault {
            Some(FaultPlan {
                point: CrashPoint::AfterAppend(n),
            }) if self.appends == n => (frame.len(), true),
            Some(FaultPlan {
                point: CrashPoint::MidAppend { record, keep },
            }) if self.appends == record => (keep.min(frame.len()), true),
            _ => (frame.len(), false),
        };
        self.file.write_all(&frame[..write_len])?;
        if self.cfg.fsync {
            self.file.sync_data()?;
        }
        if trip {
            self.tripped = true;
            return Ok(Appended {
                crash_before_apply: true,
            });
        }
        self.since_snapshot += 1;
        Ok(Appended {
            crash_before_apply: false,
        })
    }

    /// Whether the snapshot cadence has been reached.
    pub fn snapshot_due(&self) -> bool {
        self.cfg.snapshot_every > 0 && self.since_snapshot >= self.cfg.snapshot_every
    }

    /// Atomically write a snapshot covering every record appended so far
    /// (tmp-write + rename, the `profile/store.rs` idiom), then truncate
    /// the journal. The snapshot stores `last_lsn` so a crash between
    /// the rename and the truncate is harmless — replay skips the stale
    /// records by LSN.
    pub fn write_snapshot(&mut self, state: &Json, now_ns: u64) -> Result<()> {
        let doc = Json::obj()
            .set("version", SNAPSHOT_VERSION)
            .set("last_lsn", self.last_lsn)
            .set("now_ns", now_ns)
            .set("state", state.clone());
        let path = self.dir.join(SNAPSHOT_FILE);
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, doc.encode_pretty())?;
        fs::rename(&tmp, &path)?;
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.since_snapshot = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, Duration, Priority, SimTime, TaskId};
    use crate::util::rng::Rng;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    /// A randomized record (every variant and field shape reachable).
    fn random_record(rng: &mut Rng, lsn: u64) -> JournalRecord {
        let key = TaskKey::new(format!("svc-{}", rng.below(4)));
        if rng.chance(0.15) {
            return JournalRecord::Admit {
                lsn,
                task_key: key,
                shard: rng.index(4),
                service_id: rng.below(100),
            };
        }
        let msg = match rng.below(7) {
            0 => ClientMsg::Register {
                task_key: key,
                priority: Priority::from_index(rng.index(10)).unwrap(),
                has_symbols: rng.chance(0.8),
                model: if rng.chance(0.5) {
                    Some("resnet50".to_string())
                } else {
                    None
                },
            },
            1 => ClientMsg::TaskStart {
                task_key: key,
                task_id: TaskId(rng.below(8)),
            },
            2 => ClientMsg::Launch {
                task_key: key,
                task_id: TaskId(rng.below(8)),
                kernel_name: format!("k{}", rng.below(6)),
                grid: Dim3::x(1 + rng.below(64) as u32),
                block: Dim3::x(32),
                seq: rng.below(1000) as u32,
                issued_at: SimTime(rng.below(1 << 40)),
            },
            3 => ClientMsg::Completion {
                task_key: key,
                task_id: TaskId(rng.below(8)),
                seq: rng.below(1000) as u32,
                exec: Duration::from_nanos(rng.below(1 << 30)),
                finished_at: SimTime(rng.below(1 << 40)),
            },
            4 => ClientMsg::TaskEnd {
                task_key: key,
                task_id: TaskId(rng.below(8)),
            },
            5 => ClientMsg::Disconnect { task_key: key },
            _ => ClientMsg::ReleaseQuery {
                task_key: key,
                seq: rng.below(1000) as u32,
            },
        };
        JournalRecord::Apply {
            lsn,
            now_ns: rng.next_u64() >> 20,
            msg_seq: rng.below(1 << 20),
            addr: addr(1024 + rng.below(1000) as u16),
            msg,
        }
    }

    fn encode_all(records: &[JournalRecord]) -> Vec<u8> {
        let mut bytes = Vec::new();
        for r in records {
            bytes.extend_from_slice(&r.encode());
        }
        bytes
    }

    /// Satellite property 1: encode/decode round-trip over randomized
    /// record sequences, across seeds.
    #[test]
    fn codec_round_trip_randomized_sequences() {
        for seed in [1u64, 0xDEAD_BEEF, 0x5EED_5EED] {
            let mut rng = Rng::new(seed);
            let records: Vec<JournalRecord> = (0..64)
                .map(|i| random_record(&mut rng, i + 1))
                .collect();
            let outcome = scan(&encode_all(&records)).unwrap();
            assert!(!outcome.torn);
            assert_eq!(outcome.records, records, "seed {seed:#x}");
        }
    }

    /// Satellite property 2: truncating the stream at EVERY byte offset
    /// recovers exactly the records fully contained in the prefix —
    /// never an error, never a phantom record.
    #[test]
    fn torn_tail_truncation_at_every_byte_offset() {
        let mut rng = Rng::new(42);
        let records: Vec<JournalRecord> =
            (0..8).map(|i| random_record(&mut rng, i + 1)).collect();
        let frames: Vec<Vec<u8>> = records.iter().map(JournalRecord::encode).collect();
        let bytes = encode_all(&records);
        // Frame boundaries: records fully contained below each offset.
        let mut boundaries = Vec::new();
        let mut acc = 0usize;
        for f in &frames {
            acc += f.len();
            boundaries.push(acc);
        }
        for cut in 0..=bytes.len() {
            let outcome = scan(&bytes[..cut])
                .unwrap_or_else(|e| panic!("cut at {cut} must not error: {e}"));
            let complete = boundaries.iter().filter(|&&b| b <= cut).count();
            assert_eq!(
                outcome.records.len(),
                complete,
                "cut at byte {cut}: longest valid prefix"
            );
            assert_eq!(outcome.records[..], records[..complete]);
            assert_eq!(outcome.valid_len as usize, boundaries[..complete].last().copied().unwrap_or(0));
            assert_eq!(outcome.torn, cut != outcome.valid_len as usize);
        }
    }

    /// Satellite property 3: a corrupted checksum mid-file fails loudly
    /// instead of silently skipping — and so do a corrupted payload byte
    /// and an insane length prefix.
    #[test]
    fn corrupted_checksum_mid_file_fails_loudly() {
        let mut rng = Rng::new(7);
        let records: Vec<JournalRecord> =
            (0..5).map(|i| random_record(&mut rng, i + 1)).collect();
        let first_len = records[0].encode().len();
        let bytes = encode_all(&records);

        // Flip one bit in record 1's stored CRC (mid-file).
        let mut crc_bad = bytes.clone();
        crc_bad[first_len + 4] ^= 0x01;
        let err = scan(&crc_bad).unwrap_err();
        assert!(
            err.to_string().contains("checksum mismatch"),
            "unexpected error: {err}"
        );

        // Flip one payload byte of record 1 (checksum catches it).
        let mut payload_bad = bytes.clone();
        payload_bad[first_len + 8] ^= 0x40;
        assert!(scan(&payload_bad).is_err());

        // Corrupt record 1's length prefix to an insane value.
        let mut len_bad = bytes.clone();
        len_bad[first_len..first_len + 4]
            .copy_from_slice(&(u32::MAX).to_le_bytes());
        let err = scan(&len_bad).unwrap_err();
        assert!(
            err.to_string().contains("length prefix"),
            "unexpected error: {err}"
        );
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fikit-journal-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn open_append_reopen_replays_tail() {
        let dir = temp_dir("reopen");
        let mut rng = Rng::new(3);
        let recovered = Journal::open(&dir, JournalConfig::default()).unwrap();
        assert!(recovered.snapshot.is_none());
        assert!(recovered.tail.is_empty());
        let mut j = recovered.journal;
        let mut written = Vec::new();
        for _ in 0..6 {
            let lsn = j.alloc_lsn();
            let rec = random_record(&mut rng, lsn);
            assert!(!j.append(&rec).unwrap().crash_before_apply);
            written.push(rec);
        }
        drop(j);
        let recovered = Journal::open(&dir, JournalConfig::default()).unwrap();
        assert_eq!(recovered.tail, written);
        assert!(!recovered.torn_tail);
        assert_eq!(recovered.journal.last_lsn(), 6);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_truncates_journal_and_skips_covered_records() {
        let dir = temp_dir("snap");
        let mut rng = Rng::new(9);
        let mut j = Journal::open(&dir, JournalConfig::default()).unwrap().journal;
        for _ in 0..4 {
            let lsn = j.alloc_lsn();
            j.append(&random_record(&mut rng, lsn)).unwrap();
        }
        let state = Json::obj().set("probe", 1u64);
        j.write_snapshot(&state, 777).unwrap();
        // Post-snapshot records form the new tail.
        let lsn = j.alloc_lsn();
        let tail_rec = random_record(&mut rng, lsn);
        j.append(&tail_rec).unwrap();
        drop(j);

        let recovered = Journal::open(&dir, JournalConfig::default()).unwrap();
        let snap = recovered.snapshot.expect("snapshot written");
        assert_eq!(snap.req_u64("last_lsn").unwrap(), 4);
        assert_eq!(snap.req_u64("now_ns").unwrap(), 777);
        assert_eq!(
            snap.require("state").unwrap().req_u64("probe").unwrap(),
            1
        );
        assert_eq!(recovered.tail, vec![tail_rec], "only post-snapshot records replay");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fault_plan_tears_and_trips() {
        let dir = temp_dir("fault");
        let mut rng = Rng::new(11);
        let mut j = Journal::open(&dir, JournalConfig::default()).unwrap().journal;
        j.arm(FaultPlan::new(CrashPoint::MidAppend { record: 2, keep: 5 }));
        let r1 = random_record(&mut rng, j.alloc_lsn());
        assert!(!j.append(&r1).unwrap().crash_before_apply);
        let r2 = random_record(&mut rng, j.alloc_lsn());
        assert!(j.append(&r2).unwrap().crash_before_apply, "torn append trips");
        assert!(j.tripped());
        // A dead journal swallows further appends without writing.
        let r3 = random_record(&mut rng, 99);
        assert!(j.append(&r3).unwrap().crash_before_apply);
        drop(j);

        let recovered = Journal::open(&dir, JournalConfig::default()).unwrap();
        assert!(recovered.torn_tail, "5 bytes of record 2 were cut off");
        assert_eq!(recovered.tail, vec![r1], "longest valid prefix recovered");
        // The torn bytes were truncated: appending now yields a clean file.
        let mut j = recovered.journal;
        let r4 = random_record(&mut rng, j.alloc_lsn());
        j.append(&r4).unwrap();
        drop(j);
        let recovered = Journal::open(&dir, JournalConfig::default()).unwrap();
        assert!(!recovered.torn_tail);
        assert_eq!(recovered.tail.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn after_append_crash_keeps_record_durable() {
        let dir = temp_dir("afterappend");
        let mut rng = Rng::new(13);
        let mut j = Journal::open(&dir, JournalConfig::default()).unwrap().journal;
        j.arm(FaultPlan::new(CrashPoint::AfterAppend(1)));
        let r1 = random_record(&mut rng, j.alloc_lsn());
        assert!(
            j.append(&r1).unwrap().crash_before_apply,
            "die between append and apply"
        );
        drop(j);
        let recovered = Journal::open(&dir, JournalConfig::default()).unwrap();
        assert!(!recovered.torn_tail);
        assert_eq!(recovered.tail, vec![r1], "the record IS durable — replay applies it");
        let _ = fs::remove_dir_all(&dir);
    }
}
