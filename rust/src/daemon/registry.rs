//! Service admission and shard routing (DESIGN.md §Daemon).
//!
//! The registry is the daemon's client table: one entry per registered
//! hook client, carrying its reply address, priority, assigned shard,
//! retransmit-dedup state (`last_msg_seq` + cached replies) and the
//! released-sequence record that answers `ReleaseQuery` polls.
//!
//! Placement goes through [`crate::cluster::placement::FleetState`] —
//! the same capacity-aware incremental accounting the cluster simulator
//! uses — so a service lands on a shard by policy (least-loaded by
//! default, compatibility-scored `BestMatch` when model hints are
//! given), and a full fleet rejects admission instead of oversubscribing
//! a device.
//!
//! Scoring consults the daemon's [`InterferenceModel`] (ADR-006): the
//! shards report per-completion execution dilation, the daemon routes it
//! here ([`Registry::observe_interference`]), and co-residency
//! attribution turns it into learned pairwise estimates — so a
//! long-running daemon places by what its own fleet measured, not by
//! offline priors alone. The learned state is advisory and deliberately
//! absent from journal snapshots: a restarted daemon re-learns from live
//! traffic (same trade as the refiner's in-flight accumulators,
//! ADR-004).

use crate::cluster::compat::InterferenceModel;
use crate::cluster::placement::{FleetState, PlacementPolicy, Resident};
use crate::core::{Error, Priority, Result, TaskKey};
use crate::hook::protocol::SchedulerMsg;
use crate::util::json::Json;
use crate::workload::ModelKind;
use std::collections::{HashMap, HashSet};
use std::net::SocketAddr;

/// Fallback demand model when `Register` carries no model hint: a
/// mid-weight classifier, so unhinted services still get sane
/// load-balancing demand without biasing BestMatch scoring much.
const DEFAULT_MODEL: ModelKind = ModelKind::Resnet50;

/// One registered hook client.
#[derive(Debug)]
pub struct ClientEntry {
    /// Latest reply address (re-registration updates it).
    pub addr: SocketAddr,
    pub priority: Priority,
    /// Shard (device index) this service is placed on.
    pub shard: usize,
    /// Fleet resident id (for `FleetState::evict`).
    pub service_id: u64,
    /// Highest message sequence processed from this client.
    pub last_msg_seq: u64,
    /// Replies addressed to this client from processing `last_msg_seq`
    /// — resent verbatim when the same sequence arrives again, without
    /// re-executing side effects.
    pub last_replies: Vec<SchedulerMsg>,
    /// Kernel seqs already released to this client (immediate, filled or
    /// drained). Answers `ReleaseQuery` when the release datagram was
    /// dropped. Cleared on `TaskEnd` (seqs may be reused by the next
    /// task); the whole entry goes on `Disconnect`.
    pub released: HashSet<u32>,
}

/// What `Register` resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Newly placed onto this shard.
    Placed(usize),
    /// Already registered; kept its shard (address/priority refreshed).
    Refreshed(usize),
    /// Every device is at capacity — the service was turned away.
    Rejected,
}

/// The daemon's client table + fleet capacity accounting.
pub struct Registry {
    clients: HashMap<TaskKey, ClientEntry>,
    fleet: FleetState,
    policy: PlacementPolicy,
    interference: InterferenceModel,
    next_service_id: u64,
}

impl Registry {
    pub fn new(devices: usize, capacity: usize, policy: PlacementPolicy) -> Registry {
        Registry {
            clients: HashMap::new(),
            fleet: FleetState::new(devices, capacity),
            policy,
            interference: InterferenceModel::default(),
            next_service_id: 0,
        }
    }

    /// The learned interference model placement scores against.
    pub fn interference(&self) -> &InterferenceModel {
        &self.interference
    }

    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    pub fn get(&self, key: &TaskKey) -> Option<&ClientEntry> {
        self.clients.get(key)
    }

    pub fn get_mut(&mut self, key: &TaskKey) -> Option<&mut ClientEntry> {
        self.clients.get_mut(key)
    }

    /// Services currently resident across the fleet (capacity view).
    pub fn total_residents(&self) -> usize {
        self.fleet.total_residents()
    }

    /// Admit (or refresh) a service. A new service is placed by policy
    /// through the fleet's capacity accounting; re-registration keeps
    /// the shard and refreshes address/priority — so `Register`
    /// retransmits and client restarts are idempotent with respect to
    /// placement.
    pub fn register(
        &mut self,
        key: &TaskKey,
        priority: Priority,
        model_hint: Option<&str>,
        addr: SocketAddr,
        msg_seq: u64,
    ) -> Admission {
        let model = model_hint
            .and_then(|m| m.parse::<ModelKind>().ok())
            .unwrap_or(DEFAULT_MODEL);
        if let Some(entry) = self.clients.get_mut(key) {
            entry.addr = addr;
            entry.priority = priority;
            // A fresh Register starts a new client session: accept its
            // msg_seq as the new baseline (a restarted client restarts
            // its counter).
            entry.last_msg_seq = msg_seq;
            entry.last_replies.clear();
            entry.released.clear();
            // Keep the fleet's capacity/compat accounting in step with
            // the announced parameters — the service keeps its device.
            self.fleet.requalify(
                entry.service_id,
                model,
                priority,
                model.spec().mean_exec().as_millis_f64(),
            );
            return Admission::Refreshed(entry.shard);
        }
        let id = self.next_service_id;
        let resident = Resident::per_task(id, model, priority);
        let Some(shard) = self.fleet.place(self.policy, resident, &self.interference) else {
            return Admission::Rejected;
        };
        self.next_service_id += 1;
        self.clients.insert(
            key.clone(),
            ClientEntry {
                addr,
                priority,
                shard,
                service_id: id,
                last_msg_seq: msg_seq,
                last_replies: Vec::new(),
                released: HashSet::new(),
            },
        );
        Admission::Placed(shard)
    }

    /// Remove a departed service and free its fleet slot. Returns its
    /// shard, or `None` if it was never registered (idempotent).
    pub fn disconnect(&mut self, key: &TaskKey) -> Option<usize> {
        let entry = self.clients.remove(key)?;
        self.fleet.evict(entry.service_id);
        Some(entry.shard)
    }

    /// Feed one observed execution dilation (measured ÷ predicted kernel
    /// time) from a completed kernel into the interference model, with
    /// co-residency attribution: the reporting service is the victim and
    /// every other service resident on its shard is charged as a
    /// potential aggressor. Unknown keys are ignored (the client may have
    /// disconnected between completion and drain), as are solo residents
    /// (no co-tenant to blame). Allocation-free in steady state.
    pub fn observe_interference(&mut self, victim_key: &TaskKey, dilation: f64) {
        let Some(entry) = self.clients.get(victim_key) else {
            return;
        };
        let (shard, victim_id) = (entry.shard, entry.service_id);
        let residents = self.fleet.residents_on(shard);
        let Some(victim) = residents.iter().find(|r| r.id == victim_id) else {
            return;
        };
        let victim_model = victim.model;
        for r in residents {
            if r.id != victim_id {
                self.interference.observe(victim_model, r.model, dilation);
            }
        }
    }

    /// Deterministic JSON image of the client table and fleet residency —
    /// the registry's part of the daemon's journal snapshot (ADR-004).
    /// Clients and released-seq sets are sorted so identical state
    /// encodes to identical bytes regardless of hash-map order; the
    /// recovery tests compare these images directly.
    pub fn snapshot_json(&self) -> Json {
        let mut keys: Vec<&TaskKey> = self.clients.keys().collect();
        keys.sort();
        let clients: Vec<Json> = keys
            .iter()
            .map(|key| {
                let e = &self.clients[*key];
                let mut released: Vec<u32> = e.released.iter().copied().collect();
                released.sort_unstable();
                Json::obj()
                    .set("task_key", key.as_str())
                    .set("addr", e.addr.to_string().as_str())
                    .set("priority", e.priority.to_string().as_str())
                    .set("shard", e.shard)
                    .set("service_id", e.service_id)
                    .set("last_msg_seq", e.last_msg_seq)
                    .set(
                        "last_replies",
                        Json::Arr(e.last_replies.iter().map(SchedulerMsg::to_json).collect()),
                    )
                    .set(
                        "released",
                        Json::Arr(released.into_iter().map(Json::from).collect()),
                    )
            })
            .collect();
        let fleet: Vec<Json> = (0..self.fleet.gpus())
            .map(|gpu| {
                Json::Arr(
                    self.fleet
                        .residents_on(gpu)
                        .iter()
                        .map(|r| {
                            Json::obj()
                                .set("id", r.id)
                                .set("model", r.model.to_string().as_str())
                                .set("priority", r.priority.to_string().as_str())
                                .set("demand_ms", r.demand_ms)
                        })
                        .collect(),
                )
            })
            .collect();
        Json::obj()
            .set("next_service_id", self.next_service_id)
            .set("clients", Json::Arr(clients))
            .set("fleet", Json::Arr(fleet))
    }

    /// Rebuild a registry from [`Registry::snapshot_json`] output.
    /// Residents go back onto the exact GPUs the snapshot recorded (via
    /// `FleetState::admit_at`, not today's policy), so a restarted daemon
    /// rejects no previously admitted, still-live session and changes
    /// nobody's device.
    pub fn restore_snapshot(
        v: &Json,
        devices: usize,
        capacity: usize,
        policy: PlacementPolicy,
    ) -> Result<Registry> {
        let mut fleet = FleetState::new(devices, capacity);
        let gpus = v.req_arr("fleet")?;
        if gpus.len() > devices {
            return Err(Error::Config(format!(
                "journal snapshot spans {} devices but the daemon is configured \
                 for {devices}",
                gpus.len()
            )));
        }
        for (gpu, residents) in gpus.iter().enumerate() {
            for r in residents
                .as_arr()
                .ok_or_else(|| Error::Protocol("fleet gpu entry must be an array".into()))?
            {
                let resident = Resident {
                    id: r.req_u64("id")?,
                    model: r.req_str("model")?.parse()?,
                    priority: r.req_str("priority")?.parse()?,
                    demand_ms: r.req_f64("demand_ms")?,
                };
                let id = resident.id;
                if !fleet.admit_at(gpu, resident) {
                    return Err(Error::Invariant(format!(
                        "snapshot restore could not re-seat service {id} on gpu {gpu}"
                    )));
                }
            }
        }
        let mut clients = HashMap::new();
        let mut next_service_id = v.req_u64("next_service_id")?;
        for c in v.req_arr("clients")? {
            let key = TaskKey::new(c.req_str("task_key")?);
            let entry = ClientEntry {
                addr: c
                    .req_str("addr")?
                    .parse()
                    .map_err(|_| Error::Protocol("snapshot client has a bad addr".into()))?,
                priority: c.req_str("priority")?.parse()?,
                shard: c.req_u64("shard")? as usize,
                service_id: c.req_u64("service_id")?,
                last_msg_seq: c.req_u64("last_msg_seq")?,
                last_replies: c
                    .req_arr("last_replies")?
                    .iter()
                    .map(SchedulerMsg::from_json)
                    .collect::<Result<Vec<_>>>()?,
                released: c
                    .req_arr("released")?
                    .iter()
                    .map(|s| {
                        s.as_u64().and_then(|s| u32::try_from(s).ok()).ok_or_else(|| {
                            Error::Protocol("released seq out of range".into())
                        })
                    })
                    .collect::<Result<HashSet<u32>>>()?,
            };
            if entry.shard >= devices {
                return Err(Error::Invariant(format!(
                    "snapshot client {} sits on shard {} of {devices}",
                    key.as_str(),
                    entry.shard
                )));
            }
            next_service_id = next_service_id.max(entry.service_id + 1);
            clients.insert(key, entry);
        }
        Ok(Registry {
            clients,
            fleet,
            policy,
            interference: InterferenceModel::default(),
            next_service_id,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    #[test]
    fn placement_spreads_and_respects_capacity() {
        let mut r = Registry::new(2, 1, PlacementPolicy::LeastLoaded);
        assert_eq!(
            r.register(&TaskKey::new("a"), Priority::P0, None, addr(1), 1),
            Admission::Placed(0)
        );
        assert_eq!(
            r.register(&TaskKey::new("b"), Priority::P4, None, addr(2), 1),
            Admission::Placed(1)
        );
        // Fleet full → rejected, not oversubscribed.
        assert_eq!(
            r.register(&TaskKey::new("c"), Priority::P4, None, addr(3), 1),
            Admission::Rejected
        );
        assert_eq!(r.total_residents(), 2);
        // Departure frees the slot for the next arrival.
        assert_eq!(r.disconnect(&TaskKey::new("a")), Some(0));
        assert_eq!(r.disconnect(&TaskKey::new("a")), None, "idempotent");
        assert_eq!(
            r.register(&TaskKey::new("c"), Priority::P4, None, addr(3), 1),
            Admission::Placed(0)
        );
    }

    #[test]
    fn re_registration_keeps_shard_and_resets_session() {
        let mut r = Registry::new(2, 4, PlacementPolicy::LeastLoaded);
        let Admission::Placed(shard) =
            r.register(&TaskKey::new("a"), Priority::P3, Some("vgg16"), addr(1), 5)
        else {
            panic!("expected placement");
        };
        let entry = r.get_mut(&TaskKey::new("a")).unwrap();
        entry.last_msg_seq = 40;
        entry.released.insert(7);
        // Client restarted: counter went backwards, address moved.
        assert_eq!(
            r.register(&TaskKey::new("a"), Priority::P2, Some("vgg16"), addr(9), 1),
            Admission::Refreshed(shard)
        );
        let entry = r.get(&TaskKey::new("a")).unwrap();
        assert_eq!(entry.addr, addr(9));
        assert_eq!(entry.priority, Priority::P2);
        assert_eq!(entry.last_msg_seq, 1, "new session baseline accepted");
        assert!(entry.released.is_empty(), "stale releases dropped");
        assert_eq!(r.total_residents(), 1, "no double-count in the fleet");
    }

    #[test]
    fn completion_dilation_feeds_the_interference_model() {
        let mut r = Registry::new(1, 4, PlacementPolicy::BestMatch);
        let victim = TaskKey::new("v");
        let aggressor = TaskKey::new("a");
        r.register(
            &victim,
            Priority::P0,
            Some("keypointrcnn_resnet50_fpn"),
            addr(1),
            1,
        );
        r.register(&aggressor, Priority::P6, Some("googlenet"), addr(2), 1);
        for _ in 0..8 {
            r.observe_interference(&victim, 3.0);
        }
        let learned = r
            .interference()
            .learned(ModelKind::KeypointRcnnResnet50Fpn, ModelKind::Googlenet)
            .expect("co-residency attribution should have recorded the pair");
        assert_eq!(learned.1, 8, "every dilation sample lands");
        assert!(learned.0 > 1.5, "EWMA pulled toward the observed 3.0x");
        // Unknown keys (raced disconnects) are ignored, not a panic.
        r.observe_interference(&TaskKey::new("ghost"), 9.0);
        assert_eq!(r.interference().observations(), 8);
        // A solo resident has nobody to blame.
        r.disconnect(&aggressor);
        r.observe_interference(&victim, 3.0);
        assert_eq!(r.interference().observations(), 8);
    }

    #[test]
    fn unknown_model_hint_falls_back_to_default() {
        let mut r = Registry::new(1, 2, PlacementPolicy::BestMatch);
        assert_eq!(
            r.register(&TaskKey::new("a"), Priority::P0, Some("no-such-model"), addr(1), 1),
            Admission::Placed(0)
        );
    }
}
