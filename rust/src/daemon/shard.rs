//! One per-GPU scheduling shard (DESIGN.md §Daemon).
//!
//! The shard is the old single-device `SchedulerServer` body made pure:
//! it owns the device's `PriorityQueues`, `FillWindow`, `Interner`,
//! active set and recently-launched-kernel map, and turns lifecycle /
//! launch / completion events into [`SchedulerMsg`]s. It never touches a
//! socket and never looks up client addresses — the daemon routes its
//! outbound messages by task key — so every lifecycle path is unit- and
//! integration-testable without timing.
//!
//! Lifecycle hygiene (the bugs this layer fixes over the old server):
//!
//! * `launched_kernels` entries are purged on `TaskEnd`/`Disconnect`
//!   instead of accumulating per `(service, seq)` forever;
//! * a disconnecting window-holder closes its `FillWindow`, its parked
//!   launches are purged from the queues, and the next holder class is
//!   promoted exactly like `TaskEnd` does;
//! * duplicate `TaskStart` is idempotent (no double-push of the active
//!   set);
//! * holder-change drains are counted as `releases_drained`, not
//!   `releases_filled` — fill-rate telemetry only counts real window
//!   fills.

use crate::coordinator::fikit::{fikit_fill, FillWindow};
use crate::coordinator::queues::PriorityQueues;
use crate::core::{
    Duration, Error, Interner, KernelId, KernelLaunch, Priority, Result, SimTime, TaskHandle,
    TaskId, TaskKey,
};
use crate::hook::protocol::SchedulerMsg;
use crate::profile::{KeyedRefiner, OnlineConfig, ProfileStore, RefinerStats, TaskProfile};
use crate::util::json::Json;
use std::collections::HashMap;

/// Counters exposed per shard (and summed fleet-wide by the daemon).
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// `Register` messages placed onto this shard.
    pub registered: u64,
    /// `Launch` messages received.
    pub launches: u64,
    /// Launches released immediately (holder-class).
    pub releases_immediate: u64,
    /// Launches parked in the priority queues.
    pub holds: u64,
    /// Held launches released through fill windows (and only those —
    /// the honest numerator of fill-rate telemetry).
    pub releases_filled: u64,
    /// Held launches released by a holder-class drain on `TaskEnd` /
    /// `Disconnect` promotion (no window involved).
    pub releases_drained: u64,
    /// Parked launches purged because their service disconnected.
    pub purged_launches: u64,
    /// Duplicate `TaskStart` events ignored (already active).
    pub duplicate_task_starts: u64,
    /// Fill windows opened.
    pub windows: u64,
    /// Windows closed early by holder feedback.
    pub early_stops: u64,
    /// Released fill launches re-parked after a device-side preemption
    /// (ADR-007). Distinct from `holds` (first-time parks) and never
    /// counted as a fill release — fill-rate telemetry stays honest.
    pub reparked: u64,
}

impl ServerStats {
    /// Deterministic JSON image (journal snapshots, ADR-004).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("registered", self.registered)
            .set("launches", self.launches)
            .set("releases_immediate", self.releases_immediate)
            .set("holds", self.holds)
            .set("releases_filled", self.releases_filled)
            .set("releases_drained", self.releases_drained)
            .set("purged_launches", self.purged_launches)
            .set("duplicate_task_starts", self.duplicate_task_starts)
            .set("windows", self.windows)
            .set("early_stops", self.early_stops)
            .set("reparked", self.reparked)
    }

    /// Inverse of [`ServerStats::to_json`].
    pub fn from_json(v: &Json) -> Result<ServerStats> {
        Ok(ServerStats {
            registered: v.req_u64("registered")?,
            launches: v.req_u64("launches")?,
            releases_immediate: v.req_u64("releases_immediate")?,
            holds: v.req_u64("holds")?,
            releases_filled: v.req_u64("releases_filled")?,
            releases_drained: v.req_u64("releases_drained")?,
            purged_launches: v.req_u64("purged_launches")?,
            duplicate_task_starts: v.req_u64("duplicate_task_starts")?,
            windows: v.req_u64("windows")?,
            early_stops: v.req_u64("early_stops")?,
            // Absent in pre-preemption snapshots: old journals replay
            // cleanly with the counter at zero.
            reparked: v.req_u64("reparked").unwrap_or(0),
        })
    }

    /// Field-wise sum (fleet aggregation).
    pub fn add(&mut self, other: &ServerStats) {
        self.registered += other.registered;
        self.launches += other.launches;
        self.releases_immediate += other.releases_immediate;
        self.holds += other.holds;
        self.releases_filled += other.releases_filled;
        self.releases_drained += other.releases_drained;
        self.purged_launches += other.purged_launches;
        self.duplicate_task_starts += other.duplicate_task_starts;
        self.windows += other.windows;
        self.early_stops += other.early_stops;
        self.reparked += other.reparked;
    }
}

/// Map sizes of one shard — the leak probes the integration tests
/// assert on ("zero daemon-side map growth after churn").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSizes {
    /// Services currently in the active set.
    pub active: usize,
    /// Launches parked in the priority queues.
    pub queued: usize,
    /// `(service, seq) → kernel` entries awaiting a `Completion`.
    pub launched_kernels: usize,
    /// Interned task keys (append-only; bounded by distinct holder
    /// services ever seen, NOT by traffic volume).
    pub interned_tasks: usize,
    /// Interned kernel ids (same bound).
    pub interned_kernels: usize,
    /// Services tracked by the online refiner (purged on disconnect —
    /// bounded by connected services, like the other maps).
    pub refiner_tasks: usize,
}

/// One device's scheduling state inside the daemon.
pub struct Shard {
    epsilon: Duration,
    active: Vec<(TaskKey, Priority)>,
    queues: PriorityQueues,
    window: Option<FillWindow>,
    /// Identity interner for fill-window holders. Only *holder* task
    /// keys are interned (when a window opens — bounded by registered,
    /// active services); arbitrary wire traffic must never mint handles,
    /// or hostile/buggy clients could grow the interner without bound.
    interner: Interner,
    /// Kernel ids of recently released holder launches, so `Completion`
    /// messages (which carry only task/seq) can look up the profiled
    /// gap. Purged when the service's task ends or it disconnects.
    launched_kernels: HashMap<(TaskKey, u32), KernelId>,
    /// Sharing-stage refiner (DESIGN.md §9): learns per-kernel SK from
    /// wire `Completion` exec times and SG from completion→next-launch
    /// arrival gaps — the daemon-side analogue of the driver's
    /// `OnlineRefiner`, at the wire boundary where keys are strings.
    /// One per shard; the daemon harvests [`Shard::take_refined`] and
    /// shadows its profile store with the results.
    refiner: KeyedRefiner,
    /// Observed per-completion execution dilation (`measured exec /
    /// profiled SK`) awaiting harvest — the daemon drains this every
    /// `Completion` and feeds the registry's interference model
    /// (ADR-006) with co-residency attribution. Bounded: drained on the
    /// very message that filled it.
    dilations: Vec<(TaskKey, f64)>,
    stats: ServerStats,
}

impl Shard {
    pub fn new(epsilon: Duration) -> Shard {
        Shard::with_online(epsilon, OnlineConfig::default())
    }

    /// A shard with an explicit online-refinement config (the default
    /// [`Shard::new`] keeps refinement off, matching the paper's frozen
    /// profiles).
    pub fn with_online(epsilon: Duration, online: OnlineConfig) -> Shard {
        Shard {
            epsilon,
            active: Vec::new(),
            queues: PriorityQueues::new(),
            window: None,
            interner: Interner::new(),
            launched_kernels: HashMap::new(),
            refiner: KeyedRefiner::new(online),
            dilations: Vec::new(),
            stats: ServerStats::default(),
        }
    }

    /// Counters accumulated so far.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    pub fn stats_mut(&mut self) -> &mut ServerStats {
        &mut self.stats
    }

    /// Current map sizes (leak probes).
    pub fn sizes(&self) -> ShardSizes {
        ShardSizes {
            active: self.active.len(),
            queued: self.queues.len(),
            launched_kernels: self.launched_kernels.len(),
            interned_tasks: self.interner.task_count(),
            interned_kernels: self.interner.kernel_count(),
            refiner_tasks: self.refiner.tracked_tasks(),
        }
    }

    /// Refinement counters of this shard.
    pub fn refiner_stats(&self) -> &RefinerStats {
        self.refiner.stats()
    }

    /// Harvest refined profiles for services whose observed behaviour
    /// drifted outside the confidence band (empty when refinement is
    /// off or nothing drifted). The daemon installs these into its
    /// store — and persists them, so a restarted daemon resumes from
    /// the refined predictions (`rust/docs/profile-format.md`).
    pub fn take_refined(&mut self, profiles: &ProfileStore) -> Vec<TaskProfile> {
        self.refiner.take_refined(profiles)
    }

    /// Whether a fill window is currently open.
    pub fn window_open(&self) -> bool {
        self.window.is_some()
    }

    /// Whether a held launch of `key` with kernel sequence `seq` is
    /// still parked here (`ReleaseQuery` recovery path).
    pub fn is_queued(&self, key: &TaskKey, seq: u32) -> bool {
        self.queues.contains(key, seq)
    }

    fn holder(&self) -> Option<(TaskKey, Priority)> {
        self.active.iter().min_by_key(|(_, p)| *p).cloned()
    }

    /// A task (invocation) of `key` started. Idempotent: a retransmitted
    /// or duplicate `TaskStart` never double-pushes the active set.
    pub fn task_start(&mut self, key: &TaskKey, prio: Priority) {
        if self.active.iter().any(|(k, _)| k == key) {
            self.stats.duplicate_task_starts += 1;
            return;
        }
        // Preemption: a higher-priority arrival invalidates the current
        // window.
        if let Some((_, hp)) = self.holder() {
            if prio.is_higher_than(hp) {
                self.window = None;
            }
        }
        self.active.push((key.clone(), prio));
    }

    /// A task of `key` ended: retire it from the active set, drop its
    /// completion-lookup entries and its window, then promote the new
    /// holder class (their parked launches drain).
    pub fn task_end(&mut self, key: &TaskKey) -> Vec<SchedulerMsg> {
        self.active.retain(|(k, _)| k != key);
        self.retire(key);
        // The gap between this task's last completion and the *next*
        // task's first launch is inter-invocation idle, not a
        // post-kernel think gap — never fold it into SG.
        self.refiner.clear_pending(key);
        self.promote_holder_class()
    }

    /// `key`'s hook client disconnected: full lifecycle teardown — the
    /// active entry, the window it may hold, its completion-lookup
    /// entries AND its parked launches all go, then the new holder class
    /// is promoted exactly like `TaskEnd`.
    pub fn disconnect(&mut self, key: &TaskKey) -> Vec<SchedulerMsg> {
        self.active.retain(|(k, _)| k != key);
        self.retire(key);
        let purged = self.queues.purge_where(|l| &l.task_key == key);
        self.stats.purged_launches += purged.len() as u64;
        // A departed service's online estimates go with it (the refiner
        // map is bounded by connected services, like every other map).
        self.refiner.forget(key);
        self.promote_holder_class()
    }

    /// Shared `TaskEnd`/`Disconnect` teardown: completion-lookup purge
    /// (the old `launched_kernels` leak) and window invalidation.
    fn retire(&mut self, key: &TaskKey) {
        self.launched_kernels.retain(|(k, _), _| k != key);
        // Non-minting lookup: a key never interned cannot be the window
        // holder, and minting here would let arbitrary wire traffic grow
        // the interner unboundedly.
        let ended: Option<TaskHandle> = self.interner.task_handle(key);
        if self
            .window
            .as_ref()
            .is_some_and(|w| Some(w.holder) == ended)
        {
            self.window = None;
        }
    }

    /// Release every parked launch of the (new) holder class. Counted as
    /// `releases_drained` — no fill window is involved.
    fn promote_holder_class(&mut self) -> Vec<SchedulerMsg> {
        let mut out = Vec::new();
        if let Some((_, hp)) = self.holder() {
            for req in self.queues.drain_at(hp) {
                self.stats.releases_drained += 1;
                out.push(SchedulerMsg::LaunchNow {
                    task_key: req.launch.task_key.clone(),
                    task_id: req.launch.task_id,
                    seq: req.launch.seq,
                });
            }
        }
        out
    }

    /// An intercepted kernel launch arrived. Holder-class → immediate
    /// release (plus feedback early-stop); otherwise park it and pump
    /// the open window.
    #[allow(clippy::too_many_arguments)]
    pub fn launch(
        &mut self,
        key: &TaskKey,
        prio: Priority,
        task_id: TaskId,
        kernel: KernelId,
        seq: u32,
        profiles: &ProfileStore,
        now: SimTime,
    ) -> Vec<SchedulerMsg> {
        self.stats.launches += 1;
        let holder = self.holder();
        let holder_class = match &holder {
            None => true,
            Some((hk, hp)) => hk == key || *hp == prio,
        };
        if holder_class {
            // Feedback early stop: the gap ended.
            if holder.as_ref().is_some_and(|(hk, _)| hk == key) && self.window.take().is_some() {
                self.stats.early_stops += 1;
            }
            // This launch's arrival closes the service's pending
            // completion→launch gap observation (sharing-stage SG
            // learning at zero kernel-timing cost; DESIGN.md §9).
            self.refiner.observe_next_launch(key, now);
            self.stats.releases_immediate += 1;
            self.launched_kernels.insert((key.clone(), seq), kernel);
            vec![SchedulerMsg::LaunchNow {
                task_key: key.clone(),
                task_id,
                seq,
            }]
        } else {
            self.stats.holds += 1;
            // Wire boundary: the prediction is resolved from the
            // string-keyed store here, and release messages address
            // clients by task key — held launches never consume their
            // handles, so nothing is interned (minting per wire message
            // would let arbitrary clients grow the interner unboundedly).
            let predicted = profiles.get(key).and_then(|p| p.sk(&kernel));
            let launch = KernelLaunch {
                task_handle: TaskHandle::UNBOUND,
                kernel_handle: crate::core::KernelHandle::UNBOUND,
                task_key: key.clone(),
                task_id,
                kernel,
                priority: prio,
                seq,
                true_duration: Duration::ZERO,
                issued_at: now,
            };
            self.queues.push_predicted(launch, predicted, now);
            let mut out = vec![SchedulerMsg::Hold {
                task_key: key.clone(),
                task_id,
                seq,
            }];
            out.extend(self.pump_fills(now));
            out
        }
    }

    /// A released fill kernel was preempted device-side (ADR-007): the
    /// launch re-enters the priority queues as a remnant indexed by its
    /// remaining duration, and the client is told to hold it again.
    /// Deliberately NOT a fill release or a fresh hold in the counters
    /// (`reparked` only), and no fill pump runs — the preemption means
    /// a higher-priority kernel is occupying the device right now.
    #[allow(clippy::too_many_arguments)]
    pub fn repark(
        &mut self,
        key: &TaskKey,
        prio: Priority,
        task_id: TaskId,
        kernel: KernelId,
        seq: u32,
        remaining: Duration,
        now: SimTime,
    ) -> Vec<SchedulerMsg> {
        self.stats.reparked += 1;
        // Wire boundary: UNBOUND handles, exactly like first-time parks
        // in [`Shard::launch`] — re-parked launches never mint handles.
        let launch = KernelLaunch {
            task_handle: TaskHandle::UNBOUND,
            kernel_handle: crate::core::KernelHandle::UNBOUND,
            task_key: key.clone(),
            task_id,
            kernel,
            priority: prio,
            seq,
            true_duration: Duration::ZERO,
            issued_at: now,
        };
        self.queues.push_remnant(launch, remaining, now);
        vec![SchedulerMsg::Hold {
            task_key: key.clone(),
            task_id,
            seq,
        }]
    }

    /// A holder kernel finished on the client's device: its profiled gap
    /// starts now — open a fill window. The lookup entry is *consumed*:
    /// each `(service, seq)` is completed at most once (retransmitted
    /// `Completion`s are replayed from the daemon's dedup cache, never
    /// re-executed), so the map is bounded by in-flight kernels, not by
    /// task length. Completions for an unknown/retired pair are no-ops.
    pub fn completion(
        &mut self,
        key: &TaskKey,
        seq: u32,
        exec: Duration,
        profiles: &ProfileStore,
        now: SimTime,
    ) -> Vec<SchedulerMsg> {
        let is_holder = self.holder().is_some_and(|(hk, _)| &hk == key);
        if !is_holder {
            return Vec::new();
        }
        let Some(kernel) = self.launched_kernels.remove(&(key.clone(), seq)) else {
            return Vec::new();
        };
        // Execution dilation vs the profiled prediction — the daemon's
        // per-completion interference signal (the profile was measured
        // solo; anything above it is co-residency pressure).
        if let Some(predicted) = profiles.get(key).and_then(|p| p.sk(&kernel)) {
            if predicted > Duration::ZERO {
                self.dilations
                    .push((key.clone(), exec.nanos() as f64 / predicted.nanos() as f64));
            }
        }
        // The wire Completion already carries the client-measured exec
        // time — fold it into the online SK estimate and arm the gap
        // observation that the next holder launch will close.
        self.refiner
            .observe_exec(key, &kernel, exec, now, profiles.get(key));
        self.open_window(key, &kernel, profiles, now)
    }

    /// Drain the per-completion dilation observations accumulated since
    /// the last harvest.
    pub fn take_dilations(&mut self) -> Vec<(TaskKey, f64)> {
        std::mem::take(&mut self.dilations)
    }

    /// Open a fill window after a holder kernel completion (split out so
    /// tests can drive it directly).
    pub fn open_window(
        &mut self,
        key: &TaskKey,
        kernel: &KernelId,
        profiles: &ProfileStore,
        now: SimTime,
    ) -> Vec<SchedulerMsg> {
        let Some(gap) = profiles.get(key).and_then(|p| p.sg(kernel)) else {
            self.window = None;
            return Vec::new();
        };
        let holder = self.interner.intern_task(key);
        self.window = FillWindow::open(holder, now, gap, self.epsilon);
        if self.window.is_some() {
            self.stats.windows += 1;
        }
        self.pump_fills(now)
    }

    fn pump_fills(&mut self, now: SimTime) -> Vec<SchedulerMsg> {
        let Some(window) = self.window.as_mut() else {
            return Vec::new();
        };
        let fits = fikit_fill(window, now, &mut self.queues);
        let mut out = Vec::new();
        for fit in fits {
            self.stats.releases_filled += 1;
            out.push(SchedulerMsg::LaunchNow {
                task_key: fit.launch.task_key.clone(),
                task_id: fit.launch.task_id,
                seq: fit.launch.seq,
            });
        }
        out
    }

    /// Deterministic JSON image of this shard's scheduling state — its
    /// part of the daemon's journal snapshot (ADR-004) and the state the
    /// recovery tests compare. Hash-keyed collections are sorted; the
    /// `active` set and the interner keep their *insertion/mint order*
    /// (holder selection breaks priority ties by arrival order, and
    /// handles are positional, so order IS state here). Deliberately
    /// absent: ε (config, not state) and the refiner's in-flight
    /// accumulators — only *published* profiles persist, so at most one
    /// un-published refinement epoch of observations is lost per restart
    /// (the documented ADR-004 trade).
    pub fn snapshot_json(&self) -> Json {
        let active: Vec<Json> = self
            .active
            .iter()
            .map(|(k, p)| {
                Json::obj()
                    .set("task_key", k.as_str())
                    .set("priority", p.to_string().as_str())
            })
            .collect();
        let interned: Vec<Json> = (0..self.interner.task_count())
            .map(|i| {
                let key = self
                    .interner
                    .task(TaskHandle::from_index(i))
                    .expect("dense handle space");
                Json::from(key.as_str())
            })
            .collect();
        let window = match &self.window {
            None => Json::Null,
            Some(w) => Json::obj()
                .set(
                    "holder",
                    self.interner
                        .task(w.holder)
                        .expect("window holder is interned")
                        .as_str(),
                )
                .set("opened_at_ns", w.opened_at.nanos())
                .set("predicted_end_ns", w.predicted_end.nanos())
                .set("budget_ns", w.budget.nanos())
                .set("fills", w.fills),
        };
        let mut launched: Vec<(&TaskKey, u32, &KernelId)> = self
            .launched_kernels
            .iter()
            .map(|((k, seq), kernel)| (k, *seq, kernel))
            .collect();
        launched.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let launched: Vec<Json> = launched
            .into_iter()
            .map(|(k, seq, kernel)| {
                Json::obj()
                    .set("task_key", k.as_str())
                    .set("seq", seq)
                    .set("kernel", kernel.canonical().as_str())
            })
            .collect();
        let mut queued = Vec::new();
        for p in Priority::ALL {
            for req in self.queues.iter_at(p) {
                queued.push(
                    Json::obj()
                        .set("task_key", req.launch.task_key.as_str())
                        .set("task_id", req.launch.task_id.0)
                        .set("kernel", req.launch.kernel.canonical().as_str())
                        .set("priority", req.launch.priority.to_string().as_str())
                        .set("seq", req.launch.seq)
                        .set("issued_at_ns", req.launch.issued_at.nanos())
                        .set("enqueued_at_ns", req.enqueued_at.nanos())
                        .set(
                            "predicted_ns",
                            match req.predicted {
                                Some(d) => Json::from(d.nanos()),
                                None => Json::Null,
                            },
                        ),
                );
            }
        }
        Json::obj()
            .set("active", Json::Arr(active))
            .set("interned", Json::Arr(interned))
            .set("window", window)
            .set("launched", Json::Arr(launched))
            .set("queued", Json::Arr(queued))
            .set("stats", self.stats.to_json())
    }

    /// Rebuild scheduling state from [`Shard::snapshot_json`] output onto
    /// a freshly constructed shard (ε and the online config come from the
    /// daemon's own configuration, not the snapshot). Task keys are
    /// re-interned in recorded mint order so restored handles are
    /// positionally identical to the originals.
    pub fn restore_snapshot(&mut self, v: &Json) -> Result<()> {
        for key in v.req_arr("interned")? {
            let key = key
                .as_str()
                .ok_or_else(|| Error::Protocol("interned entry must be a string".into()))?;
            self.interner.intern_task(&TaskKey::new(key));
        }
        for entry in v.req_arr("active")? {
            self.active.push((
                TaskKey::new(entry.req_str("task_key")?),
                entry.req_str("priority")?.parse()?,
            ));
        }
        match v.require("window")? {
            Json::Null => self.window = None,
            w => {
                let holder_key = TaskKey::new(w.req_str("holder")?);
                let holder = self.interner.task_handle(&holder_key).ok_or_else(|| {
                    Error::Invariant(format!(
                        "snapshot window holder {:?} is not interned",
                        holder_key.as_str()
                    ))
                })?;
                self.window = Some(FillWindow {
                    holder,
                    opened_at: SimTime(w.req_u64("opened_at_ns")?),
                    predicted_end: SimTime(w.req_u64("predicted_end_ns")?),
                    budget: Duration::from_nanos(w.req_u64("budget_ns")?),
                    fills: w.req_u64("fills")? as u32,
                });
            }
        }
        for entry in v.req_arr("launched")? {
            let canonical = entry.req_str("kernel")?;
            let kernel = KernelId::from_canonical(canonical).ok_or_else(|| {
                Error::Protocol(format!("bad canonical kernel id {canonical:?}"))
            })?;
            self.launched_kernels.insert(
                (
                    TaskKey::new(entry.req_str("task_key")?),
                    entry.req_u64("seq")? as u32,
                ),
                kernel,
            );
        }
        for entry in v.req_arr("queued")? {
            let canonical = entry.req_str("kernel")?;
            let kernel = KernelId::from_canonical(canonical).ok_or_else(|| {
                Error::Protocol(format!("bad canonical kernel id {canonical:?}"))
            })?;
            let launch = KernelLaunch {
                task_handle: TaskHandle::UNBOUND,
                kernel_handle: crate::core::KernelHandle::UNBOUND,
                task_key: TaskKey::new(entry.req_str("task_key")?),
                task_id: TaskId(entry.req_u64("task_id")?),
                kernel,
                priority: entry.req_str("priority")?.parse()?,
                seq: entry.req_u64("seq")? as u32,
                true_duration: Duration::ZERO,
                issued_at: SimTime(entry.req_u64("issued_at_ns")?),
            };
            let predicted = match entry.require("predicted_ns")? {
                Json::Null => None,
                d => Some(Duration::from_nanos(d.as_u64().ok_or_else(|| {
                    Error::Parse("predicted_ns is not a u64".into())
                })?)),
            };
            let enqueued_at = SimTime(entry.req_u64("enqueued_at_ns")?);
            self.queues.push_predicted(launch, predicted, enqueued_at);
        }
        self.stats = ServerStats::from_json(v.require("stats")?)?;
        Ok(())
    }
}
