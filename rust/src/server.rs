//! `fikit serve` — the UDP front of the scheduler daemon.
//!
//! The paper's deployment shape is a standalone scheduler process that
//! hook clients (one per hosted service, possibly on other machines)
//! talk to over UDP. All scheduling logic lives in [`crate::daemon`]
//! now — per-GPU [`crate::daemon::Shard`]s behind a placement
//! [`crate::daemon::Registry`] (DESIGN.md §Daemon); this module only
//! binds the socket and pumps datagrams through it with a blocking
//! `recv_from` loop (no async runtime anywhere).
//!
//! The data plane (actually running kernels) stays in the hook client,
//! exactly as in the paper — the daemon only decides *when* each held
//! launch may proceed.

use crate::cluster::control::FleetConfig;
use crate::cluster::placement::PlacementPolicy;
use crate::coordinator::fikit::DEFAULT_EPSILON;
use crate::core::{Duration, Result};
use crate::daemon::{DaemonConfig, SchedulerDaemon};
pub use crate::daemon::{DaemonStats, ServerStats};
use crate::hook::transport::{UdpServerTransport, UdpTransport};
use crate::profile::ProfileStore;
use std::net::SocketAddr;
use std::time::Duration as StdDuration;

/// Daemon configuration (UDP binding + fleet shape).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// UDP bind address, e.g. `127.0.0.1:7700`.
    pub bind: String,
    /// GPU devices served by this daemon — one scheduling shard each.
    pub devices: usize,
    /// Concurrent services one device may host (admission bound).
    pub capacity: usize,
    /// Policy routing newly registered services to devices.
    pub policy: PlacementPolicy,
    /// Small-gap threshold ε.
    pub epsilon: Duration,
    /// Runs required before a profile counts as ready.
    pub min_profile_runs: u32,
    /// Online sharing-stage profile refinement per shard (DESIGN.md §9;
    /// `fikit serve --online`).
    pub online: crate::profile::OnlineConfig,
    /// Session-journal directory (`fikit serve --journal DIR`). When set,
    /// every session-lifecycle mutation is write-ahead journaled there and
    /// the daemon replays snapshot + tail on startup (ADR-004), so a
    /// restart rejects no previously admitted still-live session.
    pub journal: Option<std::path::PathBuf>,
    /// Fleet membership: this node's advertised name (`fikit serve
    /// --advertise n0`). `None` = standalone — no beacons, over-capacity
    /// registers always shed with `RetryAfter` (ADR-005).
    pub node: Option<String>,
    /// Named peers to beacon to (`fikit serve --peers n1=host:port,…`):
    /// the capacity/health side of the federation control plane.
    pub peers: Vec<(String, String)>,
    /// Control-plane tuning: beacon cadence, missed-beacon failure
    /// detection threshold, `RetryAfter` back-off hint.
    pub fleet: FleetConfig,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            bind: "127.0.0.1:7700".to_string(),
            devices: 1,
            capacity: 32,
            policy: PlacementPolicy::LeastLoaded,
            epsilon: DEFAULT_EPSILON,
            min_profile_runs: 1,
            online: crate::profile::OnlineConfig::default(),
            journal: None,
            node: None,
            peers: Vec::new(),
            fleet: FleetConfig::default(),
        }
    }
}

/// The UDP scheduler daemon: a bound socket plus the sharded control
/// plane.
pub struct SchedulerServer {
    daemon: SchedulerDaemon,
    transport: UdpServerTransport,
}

impl SchedulerServer {
    /// Bind the daemon.
    pub fn bind(cfg: ServerConfig, profiles: ProfileStore) -> Result<SchedulerServer> {
        let transport = UdpServerTransport::bind(&cfg.bind)?;
        let dcfg = DaemonConfig {
            devices: cfg.devices,
            capacity: cfg.capacity,
            policy: cfg.policy,
            epsilon: cfg.epsilon,
            min_profile_runs: cfg.min_profile_runs,
            online: cfg.online.clone(),
            node: cfg.node.clone(),
            fleet: cfg.fleet,
        };
        let mut daemon = match &cfg.journal {
            Some(dir) => SchedulerDaemon::with_journal(
                dcfg,
                profiles,
                dir,
                crate::daemon::JournalConfig::default(),
            )?,
            None => SchedulerDaemon::new(dcfg, profiles),
        };
        // One outbound UDP link per named peer: the daemon pumps its
        // capacity/health beacon down each of them between datagrams.
        for (_name, addr) in &cfg.peers {
            daemon.add_peer_link(Box::new(UdpTransport::connect(addr)?));
        }
        Ok(SchedulerServer { daemon, transport })
    }

    /// Bound address (useful with port 0 in tests).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.transport.local_addr()
    }

    /// Fleet-wide scheduling counters (summed over shards).
    pub fn stats(&self) -> ServerStats {
        self.daemon.stats_total()
    }

    /// Wire/routing counters.
    pub fn daemon_stats(&self) -> &DaemonStats {
        self.daemon.stats()
    }

    /// The sharded control plane (probes for tests and tooling).
    pub fn daemon(&self) -> &SchedulerDaemon {
        &self.daemon
    }

    /// Serve until `deadline` elapses (`None` = forever).
    pub fn run_for(&mut self, deadline: Option<StdDuration>) -> Result<()> {
        self.daemon.serve(&self.transport, deadline, false)
    }

    /// Serve until every client that registered has disconnected (or
    /// `deadline` elapses) — clean-shutdown test harnesses use this.
    pub fn run_until_drained(&mut self, deadline: Option<StdDuration>) -> Result<()> {
        self.daemon.serve(&self.transport, deadline, true)
    }

    /// Persist the live profile store (offline + refined overlays) —
    /// `fikit serve --save-profiles PATH` calls this on exit so a
    /// restarted daemon resumes from refined predictions (DESIGN.md §9).
    pub fn save_profiles(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.daemon.save_profiles(path)
    }
}
