//! The FIKIT scheduler daemon: the paper's standalone scheduler process.
//!
//! Hook clients (one per hosted service, possibly on other machines)
//! speak the [`crate::hook::protocol`] wire format over UDP. The daemon
//! runs the control plane of the FIKIT algorithm:
//!
//! * `Register` — admit a service; tell it whether it has a ready
//!   profile (sharing stage) or must run measurement first.
//! * `TaskStart`/`TaskEnd` — track the active set; the highest-priority
//!   active service holds the GPU.
//! * `Launch` — holder-class launches are released immediately
//!   (`LaunchNow`); lower-priority launches are parked (`Hold`) in the
//!   priority queues Q0–Q9.
//! * `Completion` — a holder kernel finished on the client's GPU: open a
//!   fill window for its profiled gap `SG` and release queued kernels
//!   chosen by BestPrioFit until the budget is spent. The next holder
//!   `Launch` closes the window early (feedback).
//!
//! The data plane (actually running kernels) stays in the hook client,
//! exactly as in the paper — the daemon only decides *when* each held
//! launch may proceed.

use crate::coordinator::fikit::{FillWindow, DEFAULT_EPSILON};
use crate::coordinator::queues::PriorityQueues;
use crate::core::{
    Duration, Interner, KernelLaunch, Priority, Result, SimTime, TaskHandle, TaskKey,
};
use crate::hook::protocol::{ClientMsg, SchedulerMsg};
use crate::profile::ProfileStore;
use std::collections::HashMap;
use std::net::{SocketAddr, UdpSocket};
use std::time::{Duration as StdDuration, Instant};

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// UDP bind address, e.g. `127.0.0.1:7700`.
    pub bind: String,
    /// Small-gap threshold ε.
    pub epsilon: Duration,
    /// Runs required before a profile counts as ready.
    pub min_profile_runs: u32,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            bind: "127.0.0.1:7700".to_string(),
            epsilon: DEFAULT_EPSILON,
            min_profile_runs: 1,
        }
    }
}

#[derive(Debug, Clone)]
struct ClientState {
    addr: SocketAddr,
    priority: Priority,
}

/// Counters exposed after a run.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// `Register` messages accepted.
    pub registered: u64,
    /// `Launch` messages received.
    pub launches: u64,
    /// Launches released immediately (holder-class).
    pub releases_immediate: u64,
    /// Launches parked in the priority queues.
    pub holds: u64,
    /// Held launches released through fill windows.
    pub releases_filled: u64,
    /// Fill windows opened.
    pub windows: u64,
    /// Windows closed early by holder feedback.
    pub early_stops: u64,
    /// Datagrams that failed to decode.
    pub decode_errors: u64,
}

/// The UDP scheduler daemon.
pub struct SchedulerServer {
    cfg: ServerConfig,
    socket: UdpSocket,
    profiles: ProfileStore,
    clients: HashMap<TaskKey, ClientState>,
    active: Vec<(TaskKey, Priority)>,
    queues: PriorityQueues,
    window: Option<FillWindow>,
    /// Identity interner for fill-window holders. Only *holder* task
    /// keys are interned (when a window opens — bounded by registered,
    /// active services, like the `clients` map); arbitrary wire traffic
    /// must never mint handles, or hostile/buggy clients could grow the
    /// interner without bound.
    interner: Interner,
    /// Kernel ids of recently released launches, so `Completion`
    /// messages (which carry only task/seq) can look up the profiled
    /// gap. One entry per (service, seq), overwritten in place on reuse.
    launched_kernels: HashMap<(TaskKey, u32), crate::core::KernelId>,
    epoch: Instant,
    stats: ServerStats,
}

impl SchedulerServer {
    /// Bind the daemon.
    pub fn bind(cfg: ServerConfig, profiles: ProfileStore) -> Result<SchedulerServer> {
        let socket = UdpSocket::bind(&cfg.bind)?;
        Ok(SchedulerServer {
            cfg,
            socket,
            profiles,
            clients: HashMap::new(),
            active: Vec::new(),
            queues: PriorityQueues::new(),
            window: None,
            interner: Interner::new(),
            launched_kernels: HashMap::new(),
            epoch: Instant::now(),
            stats: ServerStats::default(),
        })
    }

    /// Bound address (useful with port 0 in tests).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.socket.local_addr()?)
    }

    /// Counters accumulated so far.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    fn now(&self) -> SimTime {
        SimTime(self.epoch.elapsed().as_nanos() as u64)
    }

    fn holder(&self) -> Option<(TaskKey, Priority)> {
        self.active
            .iter()
            .min_by_key(|(_, p)| *p)
            .cloned()
    }

    /// Serve until `deadline` elapses (`None` = forever).
    pub fn run_for(&mut self, deadline: Option<StdDuration>) -> Result<()> {
        let start = Instant::now();
        self.socket
            .set_read_timeout(Some(StdDuration::from_millis(50)))?;
        let mut buf = vec![0u8; 64 * 1024];
        loop {
            if let Some(d) = deadline {
                if start.elapsed() >= d {
                    return Ok(());
                }
            }
            match self.socket.recv_from(&mut buf) {
                Ok((n, addr)) => {
                    let replies = match ClientMsg::decode(&buf[..n]) {
                        Ok(msg) => self.handle(msg, addr),
                        Err(e) => {
                            self.stats.decode_errors += 1;
                            vec![(
                                addr,
                                SchedulerMsg::Error {
                                    message: e.to_string(),
                                },
                            )]
                        }
                    };
                    for (to, reply) in replies {
                        if let Ok(bytes) = reply.encode() {
                            self.socket.send_to(&bytes, to).ok();
                        }
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Handle one message; returns the replies to send.
    pub fn handle(&mut self, msg: ClientMsg, addr: SocketAddr) -> Vec<(SocketAddr, SchedulerMsg)> {
        match msg {
            ClientMsg::Register {
                task_key,
                priority,
                has_symbols,
            } => {
                self.stats.registered += 1;
                // Without exported symbols kernels cannot be identified —
                // profiles would be meaningless (paper §3.2), so such
                // services never reach sharing stage.
                let sharing = has_symbols
                    && self
                        .profiles
                        .has_ready(&task_key, self.cfg.min_profile_runs);
                self.clients
                    .insert(task_key.clone(), ClientState { addr, priority });
                vec![(
                    addr,
                    SchedulerMsg::Registered {
                        task_key,
                        sharing_stage: sharing,
                    },
                )]
            }
            ClientMsg::TaskStart { task_key, .. } => {
                if let Some(c) = self.clients.get(&task_key) {
                    let prio = c.priority;
                    // Preemption: a higher-priority arrival invalidates
                    // the current window.
                    if let Some((_, hp)) = self.holder() {
                        if prio.is_higher_than(hp) {
                            self.window = None;
                        }
                    }
                    self.active.push((task_key, prio));
                }
                Vec::new()
            }
            ClientMsg::TaskEnd { task_key, .. } => {
                self.active.retain(|(k, _)| k != &task_key);
                // Non-minting lookup: a key never interned cannot be the
                // window holder, and minting here would let arbitrary
                // wire traffic grow the interner unboundedly.
                let ended: Option<TaskHandle> = self.interner.task_handle(&task_key);
                if self
                    .window
                    .as_ref()
                    .is_some_and(|w| Some(w.holder) == ended)
                {
                    self.window = None;
                }
                // Release the new holder class's parked launches.
                let mut out = Vec::new();
                if let Some((_, hp)) = self.holder() {
                    for req in self.queues.drain_at(hp) {
                        if let Some(c) = self.clients.get(&req.launch.task_key) {
                            self.stats.releases_filled += 1;
                            out.push((
                                c.addr,
                                SchedulerMsg::LaunchNow {
                                    task_key: req.launch.task_key.clone(),
                                    task_id: req.launch.task_id,
                                    seq: req.launch.seq,
                                },
                            ));
                        }
                    }
                }
                out
            }
            ClientMsg::Launch {
                task_key,
                task_id,
                kernel_name,
                grid,
                block,
                seq,
                ..
            } => {
                self.stats.launches += 1;
                let now = self.now();
                let kernel = crate::hook::client::kernel_id_from_wire(&kernel_name, grid, block);
                let prio = self
                    .clients
                    .get(&task_key)
                    .map(|c| c.priority)
                    .unwrap_or(Priority::LOWEST);
                let holder = self.holder();
                let holder_class = match &holder {
                    None => true,
                    Some((hk, hp)) => hk == &task_key || *hp == prio,
                };
                if holder_class {
                    // Feedback early stop: the gap ended.
                    if holder.as_ref().is_some_and(|(hk, _)| hk == &task_key)
                        && self.window.take().is_some()
                    {
                        self.stats.early_stops += 1;
                    }
                    self.stats.releases_immediate += 1;
                    self.launched_kernels
                        .insert((task_key.clone(), seq), kernel);
                    vec![(
                        addr,
                        SchedulerMsg::LaunchNow {
                            task_key,
                            task_id,
                            seq,
                        },
                    )]
                } else {
                    self.stats.holds += 1;
                    // Wire boundary: the prediction is resolved from the
                    // string-keyed store here, and the daemon's release
                    // messages address clients by task key — held
                    // launches never consume their handles, so nothing
                    // is interned (minting per wire message would let
                    // arbitrary clients grow the interner unboundedly).
                    let predicted = self
                        .profiles
                        .get(&task_key)
                        .and_then(|p| p.sk(&kernel));
                    let launch = KernelLaunch {
                        task_handle: TaskHandle::UNBOUND,
                        kernel_handle: crate::core::KernelHandle::UNBOUND,
                        task_key: task_key.clone(),
                        task_id,
                        kernel,
                        priority: prio,
                        seq,
                        true_duration: Duration::ZERO,
                        issued_at: now,
                    };
                    self.queues.push_predicted(launch, predicted, now);
                    let mut out = vec![(
                        addr,
                        SchedulerMsg::Hold {
                            task_key,
                            task_id,
                            seq,
                        },
                    )];
                    out.extend(self.pump_fills());
                    out
                }
            }
            ClientMsg::Completion { task_key, seq, .. } => {
                // A holder kernel finished on the client's device: its
                // profiled gap starts now — open a fill window.
                let is_holder = self.holder().is_some_and(|(hk, _)| hk == task_key);
                if !is_holder {
                    return Vec::new();
                }
                let Some(kernel) = self.launched_kernels.get(&(task_key.clone(), seq)).cloned()
                else {
                    return Vec::new();
                };
                self.open_window(&task_key, &kernel)
            }
            ClientMsg::Disconnect { task_key } => {
                self.active.retain(|(k, _)| k != &task_key);
                self.clients.remove(&task_key);
                Vec::new()
            }
        }
    }

    /// Open a fill window after a holder kernel completion (called by
    /// `handle_completion` — split out so tests can drive it directly).
    pub fn open_window(&mut self, task_key: &TaskKey, kernel: &crate::core::KernelId) -> Vec<(SocketAddr, SchedulerMsg)> {
        let Some(gap) = self.profiles.get(task_key).and_then(|p| p.sg(kernel)) else {
            self.window = None;
            return Vec::new();
        };
        let now = self.now();
        let holder = self.interner.intern_task(task_key);
        self.window = FillWindow::open(holder, now, gap, self.cfg.epsilon);
        if self.window.is_some() {
            self.stats.windows += 1;
        }
        self.pump_fills()
    }

    fn pump_fills(&mut self) -> Vec<(SocketAddr, SchedulerMsg)> {
        let Some(window) = self.window.as_mut() else {
            return Vec::new();
        };
        let now = SimTime(self.epoch.elapsed().as_nanos() as u64);
        let fits = crate::coordinator::fikit::fikit_fill(window, now, &mut self.queues);
        let mut out = Vec::new();
        for fit in fits {
            if let Some(c) = self.clients.get(&fit.launch.task_key) {
                self.stats.releases_filled += 1;
                out.push((
                    c.addr,
                    SchedulerMsg::LaunchNow {
                        task_key: fit.launch.task_key.clone(),
                        task_id: fit.launch.task_id,
                        seq: fit.launch.seq,
                    },
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelId, TaskId};
    use crate::profile::TaskProfile;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(4), Dim3::x(64))
    }

    fn server_with_profiles() -> SchedulerServer {
        let mut profiles = ProfileStore::new();
        let mut hi = TaskProfile::new(TaskKey::new("hi"));
        hi.record(&kid("hk"), Duration::from_micros(200), Some(Duration::from_millis(2)));
        hi.finish_run(1);
        profiles.insert(hi);
        let mut lo = TaskProfile::new(TaskKey::new("lo"));
        lo.record(&kid("lk"), Duration::from_micros(400), Some(Duration::from_micros(20)));
        lo.finish_run(1);
        profiles.insert(lo);
        let cfg = ServerConfig {
            bind: "127.0.0.1:0".to_string(),
            ..Default::default()
        };
        SchedulerServer::bind(cfg, profiles).unwrap()
    }

    fn launch_msg(key: &str, kernel: &str, seq: u32) -> ClientMsg {
        ClientMsg::Launch {
            task_key: TaskKey::new(key),
            task_id: TaskId(0),
            kernel_name: kernel.to_string(),
            grid: Dim3::x(4),
            block: Dim3::x(64),
            seq,
            issued_at: SimTime::ZERO,
        }
    }

    #[test]
    fn register_reports_stage() {
        let mut s = server_with_profiles();
        let r = s.handle(
            ClientMsg::Register {
                task_key: TaskKey::new("hi"),
                priority: Priority::P0,
                has_symbols: true,
            },
            addr(9001),
        );
        assert!(matches!(
            r[0].1,
            SchedulerMsg::Registered { sharing_stage: true, .. }
        ));
        // Unknown service → measurement stage.
        let r = s.handle(
            ClientMsg::Register {
                task_key: TaskKey::new("new"),
                priority: Priority::P5,
                has_symbols: true,
            },
            addr(9002),
        );
        assert!(matches!(
            r[0].1,
            SchedulerMsg::Registered { sharing_stage: false, .. }
        ));
        // No symbols → never sharing stage, even with a profile.
        let r = s.handle(
            ClientMsg::Register {
                task_key: TaskKey::new("hi"),
                priority: Priority::P0,
                has_symbols: false,
            },
            addr(9001),
        );
        assert!(matches!(
            r[0].1,
            SchedulerMsg::Registered { sharing_stage: false, .. }
        ));
    }

    #[test]
    fn priority_hold_and_window_release() {
        let mut s = server_with_profiles();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            s.handle(
                ClientMsg::Register {
                    task_key: TaskKey::new(key),
                    priority: prio,
                    has_symbols: true,
                },
                addr(port),
            );
            s.handle(
                ClientMsg::TaskStart {
                    task_key: TaskKey::new(key),
                    task_id: TaskId(0),
                },
                addr(port),
            );
        }

        // Holder launch → immediate release.
        let r = s.handle(launch_msg("hi", "hk", 0), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));

        // Low-priority launch → held.
        let r = s.handle(launch_msg("lo", "lk", 0), addr(9002));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
        assert_eq!(s.stats().holds, 1);

        // Holder kernel completes → window opens → held launch released.
        let releases = s.open_window(&TaskKey::new("hi"), &kid("hk"));
        assert_eq!(releases.len(), 1);
        assert_eq!(releases[0].0, addr(9002));
        assert!(matches!(releases[0].1, SchedulerMsg::LaunchNow { seq: 0, .. }));
        assert_eq!(s.stats().windows, 1);

        // Next holder launch with the window still open → early stop.
        let r = s.handle(launch_msg("hi", "hk", 1), addr(9001));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
        assert_eq!(s.stats().early_stops, 1);
    }

    #[test]
    fn completion_message_opens_window() {
        let mut s = server_with_profiles();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            s.handle(
                ClientMsg::Register {
                    task_key: TaskKey::new(key),
                    priority: prio,
                    has_symbols: true,
                },
                addr(port),
            );
            s.handle(
                ClientMsg::TaskStart {
                    task_key: TaskKey::new(key),
                    task_id: TaskId(0),
                },
                addr(port),
            );
        }
        s.handle(launch_msg("hi", "hk", 0), addr(9001));
        s.handle(launch_msg("lo", "lk", 0), addr(9002));
        // The wire-level Completion (task/seq only) finds the kernel id
        // and opens the window, releasing the held low-prio launch.
        let r = s.handle(
            ClientMsg::Completion {
                task_key: TaskKey::new("hi"),
                task_id: TaskId(0),
                seq: 0,
                exec: Duration::from_micros(200),
                finished_at: SimTime(1),
            },
            addr(9001),
        );
        assert_eq!(r.len(), 1);
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
    }

    #[test]
    fn unknown_task_key_launch_defaults_to_lowest_priority() {
        let mut s = server_with_profiles();
        // "hi" is registered and active; a launch arrives from a service
        // that never registered — it must not jump the holder.
        s.handle(
            ClientMsg::Register {
                task_key: TaskKey::new("hi"),
                priority: Priority::P0,
                has_symbols: true,
            },
            addr(9001),
        );
        s.handle(
            ClientMsg::TaskStart {
                task_key: TaskKey::new("hi"),
                task_id: TaskId(0),
            },
            addr(9001),
        );
        let r = s.handle(launch_msg("ghost", "gk", 0), addr(9009));
        assert!(matches!(r[0].1, SchedulerMsg::Hold { .. }));
    }

    #[test]
    fn re_registration_updates_address() {
        let mut s = server_with_profiles();
        for port in [9001, 9002] {
            s.handle(
                ClientMsg::Register {
                    task_key: TaskKey::new("lo"),
                    priority: Priority::P4,
                    has_symbols: true,
                },
                addr(port),
            );
        }
        // Also a holder so lo's launch parks.
        s.handle(
            ClientMsg::Register {
                task_key: TaskKey::new("hi"),
                priority: Priority::P0,
                has_symbols: true,
            },
            addr(9000),
        );
        for key in ["hi", "lo"] {
            s.handle(
                ClientMsg::TaskStart {
                    task_key: TaskKey::new(key),
                    task_id: TaskId(0),
                },
                addr(9000),
            );
        }
        s.handle(launch_msg("hi", "hk", 0), addr(9000));
        s.handle(launch_msg("lo", "lk", 0), addr(9002));
        // Release goes to the LATEST registered address (9002).
        let releases = s.open_window(&TaskKey::new("hi"), &kid("hk"));
        assert_eq!(releases[0].0, addr(9002));
    }

    #[test]
    fn disconnect_removes_client_and_active_entry() {
        let mut s = server_with_profiles();
        s.handle(
            ClientMsg::Register {
                task_key: TaskKey::new("hi"),
                priority: Priority::P0,
                has_symbols: true,
            },
            addr(9001),
        );
        s.handle(
            ClientMsg::TaskStart {
                task_key: TaskKey::new("hi"),
                task_id: TaskId(0),
            },
            addr(9001),
        );
        s.handle(
            ClientMsg::Disconnect {
                task_key: TaskKey::new("hi"),
            },
            addr(9001),
        );
        // Re-registering after disconnect works and no stale holder blocks
        // other traffic: a fresh low-priority launch is released (no
        // active holder).
        s.handle(
            ClientMsg::Register {
                task_key: TaskKey::new("lo"),
                priority: Priority::P9,
                has_symbols: true,
            },
            addr(9002),
        );
        let r = s.handle(launch_msg("lo", "lk", 0), addr(9002));
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { .. }));
    }

    #[test]
    fn task_end_releases_new_holder_class() {
        let mut s = server_with_profiles();
        for (key, prio, port) in [("hi", Priority::P0, 9001), ("lo", Priority::P4, 9002)] {
            s.handle(
                ClientMsg::Register {
                    task_key: TaskKey::new(key),
                    priority: prio,
                    has_symbols: true,
                },
                addr(port),
            );
            s.handle(
                ClientMsg::TaskStart {
                    task_key: TaskKey::new(key),
                    task_id: TaskId(0),
                },
                addr(port),
            );
        }
        s.handle(launch_msg("lo", "lk", 3), addr(9002));
        // Holder finishes its task: lo becomes holder, gets released.
        let r = s.handle(
            ClientMsg::TaskEnd {
                task_key: TaskKey::new("hi"),
                task_id: TaskId(0),
            },
            addr(9001),
        );
        assert_eq!(r.len(), 1);
        assert!(matches!(r[0].1, SchedulerMsg::LaunchNow { seq: 3, .. }));
    }
}
