//! Calendar-queue **event wheel** — the simulator's event core
//! (DESIGN.md §Perf, ADR-003).
//!
//! A bucketed calendar queue: time is quantized into ticks of `2^shift`
//! nanoseconds, and each tick maps onto one of `N` buckets (`N` a power
//! of two) by `tick & (N-1)`. Within one rotation window
//! `[cursor, cursor + N)` the tick ↔ bucket mapping is a bijection, so
//! the bucket at the cursor holds *only* entries of the current tick and
//! a push into the window is a single `Vec::push` — O(1), no sift-up,
//! no per-entry allocation once bucket capacities are warm.
//!
//! Events landing **beyond** the rotation window go to the **overflow
//! ring**: a min-heap ordered by `(time, seq)`. The standing invariant is
//!
//! > every overflow entry's tick is `>= cursor + N`
//!
//! maintained by refilling (draining matured overflow entries into their
//! buckets) every time the cursor advances. Popping positions the cursor
//! on the next non-empty bucket (jumping straight to the overflow head's
//! tick when the wheel is empty), then min-scans that one bucket by
//! `(time, seq)` — a handful of entries in practice, since a bucket
//! spans a single tick of the current rotation.
//!
//! Determinism: `seq` is a monotone insertion counter and every pop
//! selects the globally least `(time, seq)` entry, so the wheel replays
//! *exactly* the pop order of the binary-heap queue it replaced. The
//! differential property test in `tests/sim_core.rs` pins this against
//! [`BaselineHeapQueue`] on randomized schedules.

use crate::core::SimTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Default tick width exponent: `2^16` ns ≈ 65.5 µs — the same order as
/// the smallest kernel-gap band worth scheduling around, so consecutive
/// device events land a few buckets apart and bucket occupancy stays
/// O(1).
pub const DEFAULT_SHIFT: u32 = 16;

/// Default bucket count (must be a power of two). 1024 buckets × 65.5 µs
/// ≈ 67 ms of rotation span: kernel completions, launch-ahead issues and
/// think-gap resumes all land inside the window; only coarse arrival
/// patterns (whole-run `Every` schedules) ride the overflow ring.
pub const DEFAULT_BUCKETS: usize = 1024;

/// One timestamped entry parked in a bucket.
#[derive(Debug, Clone)]
struct BucketEntry<T> {
    time: u64,
    seq: u64,
    item: T,
}

/// Overflow-ring entry; the manual `Ord` on `(time, seq)` keeps `T` free
/// of any ordering requirement.
#[derive(Debug, Clone)]
struct OverflowEntry<T> {
    time: u64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for OverflowEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for OverflowEntry<T> {}
impl<T> PartialOrd for OverflowEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for OverflowEntry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// A calendar-queue priority queue of timestamped items, popping in
/// strict `(time, insertion seq)` order.
///
/// Generic over the payload so the per-device [`EventQueue`]
/// (`simulator::Event`) and the fleet-level churn queue
/// (`cluster::sim`'s `FleetEvent`) share one implementation with
/// different geometries.
///
/// [`EventQueue`]: super::EventQueue
#[derive(Debug)]
pub struct CalendarWheel<T> {
    buckets: Box<[Vec<BucketEntry<T>>]>,
    /// `buckets.len() - 1` (bucket count is a power of two).
    mask: u64,
    /// Tick width: `2^shift` nanoseconds.
    shift: u32,
    /// Absolute tick the rotation window starts at. Never decreases
    /// while the queue is non-empty.
    cursor: u64,
    /// Entries currently parked in buckets (excludes overflow).
    in_wheel: usize,
    /// Far-future entries: min-(time, seq) heap; every entry's tick is
    /// `>= cursor + buckets.len()` (the refill invariant).
    overflow: BinaryHeap<Reverse<OverflowEntry<T>>>,
    /// Monotone insertion counter — the deterministic tie-break.
    seq: u64,
}

impl<T> Default for CalendarWheel<T> {
    fn default() -> CalendarWheel<T> {
        CalendarWheel::with_geometry(DEFAULT_SHIFT, DEFAULT_BUCKETS)
    }
}

impl<T> CalendarWheel<T> {
    /// A wheel with `2^shift`-ns ticks and `buckets` buckets (power of
    /// two). Span = `buckets << shift` nanoseconds per rotation.
    pub fn with_geometry(shift: u32, buckets: usize) -> CalendarWheel<T> {
        assert!(buckets.is_power_of_two(), "bucket count must be 2^k");
        assert!(shift < 48, "tick width exponent out of range");
        CalendarWheel {
            buckets: (0..buckets).map(|_| Vec::new()).collect(),
            mask: buckets as u64 - 1,
            shift,
            cursor: 0,
            in_wheel: 0,
            overflow: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.in_wheel + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.in_wheel == 0 && self.overflow.is_empty()
    }

    /// Schedule `item` at `time`. O(1) for the in-window band, O(log n)
    /// for overflow.
    pub fn push(&mut self, time: SimTime, item: T) {
        let seq = self.seq;
        self.seq += 1;
        let t = time.nanos();
        let tick = t >> self.shift;
        if self.is_empty() {
            // Nothing pending: snap the window to this event so a long
            // quiet gap never costs an empty-bucket scan.
            self.cursor = tick;
        }
        if tick >= self.cursor + self.buckets.len() as u64 {
            self.overflow.push(Reverse(OverflowEntry { time: t, seq, item }));
        } else {
            // A push can trail the cursor by a tick when a bounded pop
            // scanned up to its cap and the next push lands on the cap
            // tick. Clamping keeps it correct: the entry joins the
            // current bucket, which pops first, and the in-bucket
            // min-scan ranks it by its true (time, seq).
            let slot = (tick.max(self.cursor) & self.mask) as usize;
            self.buckets[slot].push(BucketEntry { time: t, seq, item });
            self.in_wheel += 1;
        }
    }

    /// Drain matured overflow entries (tick < cursor + N) into their
    /// buckets — restores the refill invariant after a cursor move.
    fn refill(&mut self) {
        let window_end = self.cursor + self.buckets.len() as u64;
        while let Some(Reverse(head)) = self.overflow.peek() {
            if head.time >> self.shift >= window_end {
                break;
            }
            let Reverse(e) = self.overflow.pop().expect("peeked entry exists");
            let slot = ((e.time >> self.shift) & self.mask) as usize;
            self.buckets[slot].push(BucketEntry {
                time: e.time,
                seq: e.seq,
                item: e.item,
            });
            self.in_wheel += 1;
        }
    }

    /// Advance the cursor to the next non-empty bucket and return its
    /// index, never stepping past `tick_cap`. `None` when the queue is
    /// empty or everything pending lies beyond the cap — in the latter
    /// case the cursor parks at `tick_cap + 1` (or stays put when only
    /// the overflow holds entries), so it never crosses a bound that a
    /// later push might land on.
    fn position_capped(&mut self, tick_cap: u64) -> Option<usize> {
        if self.in_wheel == 0 {
            // Wheel drained: jump straight to the overflow head's tick
            // instead of stepping through empty buckets. (The refill
            // invariant guarantees head_tick >= cursor + N, so this only
            // moves forward.)
            let head_tick = {
                let Reverse(head) = self.overflow.peek()?;
                head.time >> self.shift
            };
            if head_tick > tick_cap {
                return None;
            }
            self.cursor = head_tick;
            self.refill();
            debug_assert!(self.in_wheel > 0, "refill must land the overflow head");
        }
        loop {
            if self.cursor > tick_cap {
                return None;
            }
            let idx = (self.cursor & self.mask) as usize;
            if !self.buckets[idx].is_empty() {
                return Some(idx);
            }
            self.cursor += 1;
            self.refill();
        }
    }

    /// Time of the next item without removing it. (May advance the
    /// cursor to that item's tick.)
    pub fn peek_time(&mut self) -> Option<SimTime> {
        let idx = self.position_capped(u64::MAX)?;
        let t = self.buckets[idx]
            .iter()
            .map(|e| e.time)
            .min()
            .expect("positioned bucket is non-empty");
        Some(SimTime(t))
    }

    /// Index of the least `(time, seq)` entry in `bucket`.
    fn min_entry(bucket: &[BucketEntry<T>]) -> usize {
        let mut best = 0;
        for i in 1..bucket.len() {
            if (bucket[i].time, bucket[i].seq) < (bucket[best].time, bucket[best].seq) {
                best = i;
            }
        }
        best
    }

    /// Remove and return the least `(time, seq)` item.
    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        let idx = self.position_capped(u64::MAX)?;
        let bucket = &mut self.buckets[idx];
        let best = Self::min_entry(bucket);
        let e = bucket.swap_remove(best);
        self.in_wheel -= 1;
        Some((SimTime(e.time), e.item))
    }

    /// Remove and return the least `(time, seq)` item **iff** its time
    /// is `<= bound`; otherwise leave the queue untouched. The cursor
    /// never advances past `bound`'s tick, so a bulk-synchronous caller
    /// (`GpuSim::run_until` between fleet-event horizons) can keep
    /// pushing events at the bound without falling behind the window.
    pub fn pop_if_before(&mut self, bound: SimTime) -> Option<(SimTime, T)> {
        let idx = self.position_capped(bound.nanos() >> self.shift)?;
        let bucket = &mut self.buckets[idx];
        let best = Self::min_entry(bucket);
        if bucket[best].time > bound.nanos() {
            return None; // same tick, but past the bound's nanosecond.
        }
        let e = bucket.swap_remove(best);
        self.in_wheel -= 1;
        Some((SimTime(e.time), e.item))
    }

    /// Reset to empty **without releasing storage**: bucket and overflow
    /// capacities survive, so a multi-run sweep reusing one wheel pays
    /// its allocation cost once (the `EventQueue::clear` path).
    pub fn clear(&mut self) {
        for bucket in self.buckets.iter_mut() {
            bucket.clear();
        }
        self.overflow.clear();
        self.cursor = 0;
        self.in_wheel = 0;
        self.seq = 0;
    }
}

/// The binary-heap event queue the wheel replaced, kept as the reference
/// implementation: the differential property test (`tests/sim_core.rs`)
/// replays randomized schedules through both and demands identical pop
/// sequences, and `BENCH_sim.json` carries a `wheel/heap_*` comparison
/// case so the artifact documents its own before/after.
#[derive(Debug)]
pub struct BaselineHeapQueue<T> {
    heap: BinaryHeap<Reverse<OverflowEntry<T>>>,
    seq: u64,
}

impl<T> Default for BaselineHeapQueue<T> {
    fn default() -> BaselineHeapQueue<T> {
        BaselineHeapQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }
}

impl<T> BaselineHeapQueue<T> {
    pub fn new() -> BaselineHeapQueue<T> {
        BaselineHeapQueue::default()
    }

    pub fn push(&mut self, time: SimTime, item: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(OverflowEntry {
            time: time.nanos(),
            seq,
            item,
        }));
    }

    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        self.heap.pop().map(|Reverse(e)| (SimTime(e.time), e.item))
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| SimTime(e.time))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_insertion_ties() {
        let mut w: CalendarWheel<u32> = CalendarWheel::default();
        w.push(SimTime(30), 3);
        w.push(SimTime(10), 1);
        w.push(SimTime(10), 2);
        w.push(SimTime(20), 9);
        assert_eq!(w.peek_time(), Some(SimTime(10)));
        assert_eq!(w.pop(), Some((SimTime(10), 1)));
        assert_eq!(w.pop(), Some((SimTime(10), 2)));
        assert_eq!(w.pop(), Some((SimTime(20), 9)));
        assert_eq!(w.pop(), Some((SimTime(30), 3)));
        assert_eq!(w.pop(), None);
        assert!(w.is_empty());
    }

    #[test]
    fn same_tick_burst_pops_in_insertion_order() {
        let mut w: CalendarWheel<usize> = CalendarWheel::default();
        for i in 0..100 {
            w.push(SimTime(1_000_000), i);
        }
        for i in 0..100 {
            assert_eq!(w.pop(), Some((SimTime(1_000_000), i)));
        }
        assert!(w.is_empty());
    }

    #[test]
    fn far_future_rides_the_overflow_ring() {
        let mut w: CalendarWheel<u32> = CalendarWheel::with_geometry(4, 8);
        // Span = 8 * 16 ns = 128 ns; 10_000 ns is deep overflow.
        w.push(SimTime(10_000), 42);
        w.push(SimTime(5), 1);
        assert_eq!(w.len(), 2);
        assert_eq!(w.pop(), Some((SimTime(5), 1)));
        // Wheel drained → cursor jumps to the overflow head's tick.
        assert_eq!(w.pop(), Some((SimTime(10_000), 42)));
        assert!(w.is_empty());
    }

    #[test]
    fn overflow_interleaves_correctly_with_window_entries() {
        let mut w: CalendarWheel<u32> = CalendarWheel::with_geometry(4, 8);
        for i in 0..64u32 {
            // Times step past several rotations; mix near and far.
            w.push(SimTime(u64::from(i) * 40), i);
        }
        let mut prev = 0;
        for _ in 0..64 {
            let (t, _) = w.pop().unwrap();
            assert!(t.nanos() >= prev, "pop went back in time");
            prev = t.nanos();
        }
        assert!(w.is_empty());
    }

    #[test]
    fn empty_queue_snaps_cursor_forward_and_back() {
        let mut w: CalendarWheel<u32> = CalendarWheel::default();
        w.push(SimTime(1 << 40), 1);
        assert_eq!(w.pop(), Some((SimTime(1 << 40), 1)));
        // Empty again: an earlier time is acceptable (fresh epoch).
        w.push(SimTime(7), 2);
        assert_eq!(w.pop(), Some((SimTime(7), 2)));
    }

    #[test]
    fn clear_resets_order_and_reuses_storage() {
        let mut w: CalendarWheel<u32> = CalendarWheel::default();
        for i in 0..100u32 {
            w.push(SimTime(u64::from(i) * 1_000_000_000), i);
        }
        assert_eq!(w.len(), 100);
        w.clear();
        assert!(w.is_empty());
        assert_eq!(w.len(), 0);
        w.push(SimTime(20), 2);
        w.push(SimTime(10), 1);
        assert_eq!(w.pop(), Some((SimTime(10), 1)));
        assert_eq!(w.pop(), Some((SimTime(20), 2)));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn bounded_pop_stops_at_bound_without_losing_order() {
        let mut w: CalendarWheel<u32> = CalendarWheel::with_geometry(4, 8);
        w.push(SimTime(10), 1);
        w.push(SimTime(100), 2);
        w.push(SimTime(100_000), 3); // deep overflow
        assert_eq!(w.pop_if_before(SimTime(50)), Some((SimTime(10), 1)));
        assert_eq!(w.pop_if_before(SimTime(50)), None);
        assert_eq!(w.len(), 3 - 1);
        // A push right at the previous bound still pops in order even
        // though the capped scan may have parked the cursor on its tick.
        w.push(SimTime(50), 4);
        assert_eq!(w.pop_if_before(SimTime(200)), Some((SimTime(50), 4)));
        assert_eq!(w.pop_if_before(SimTime(200)), Some((SimTime(100), 2)));
        assert_eq!(w.pop_if_before(SimTime(200)), None);
        assert_eq!(w.pop(), Some((SimTime(100_000), 3)));
        assert!(w.is_empty());
    }

    #[test]
    fn bounded_pop_refuses_event_past_bound() {
        let mut w: CalendarWheel<u32> = CalendarWheel::with_geometry(4, 8);
        w.push(SimTime(100_000), 9);
        assert_eq!(w.pop_if_before(SimTime(99_999)), None);
        assert_eq!(w.len(), 1);
        assert_eq!(w.pop_if_before(SimTime(100_000)), Some((SimTime(100_000), 9)));
    }

    #[test]
    fn baseline_heap_matches_simple_sequence() {
        let mut h: BaselineHeapQueue<u32> = BaselineHeapQueue::new();
        h.push(SimTime(30), 3);
        h.push(SimTime(10), 1);
        h.push(SimTime(10), 2);
        assert_eq!(h.peek_time(), Some(SimTime(10)));
        assert_eq!(h.pop(), Some((SimTime(10), 1)));
        assert_eq!(h.pop(), Some((SimTime(10), 2)));
        assert_eq!(h.pop(), Some((SimTime(30), 3)));
        assert!(h.is_empty());
    }
}
