//! The CPU-side service process: a closed-loop launch state machine.
//!
//! One `ServiceProcess` models one hosted service (one container / one
//! hook-client in the paper's deployment): tasks arrive per the service's
//! invocation pattern, each task replays a fresh jittered kernel trace,
//! and kernel *i+1* is issued only after kernel *i*'s completion is
//! observed plus the trace's CPU-side gap (plus hook/symbol/measurement
//! overheads, which is where FIKIT's cost models attach).

use crate::core::{
    Duration, Interner, KernelHandle, KernelLaunch, KernelRecord, Priority, SimTime, TaskHandle,
    TaskId, TaskKey,
};
use crate::profile::{MeasurementConfig, MeasurementRecorder, SymbolResolver, TaskProfile};
use crate::workload::{KernelTrace, Service, TraceGenerator};
use std::collections::VecDeque;

/// Which lifecycle stage the service is in (paper Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Kernel-level measurement with timing events (expensive, exclusive).
    Measuring,
    /// Long-term serving with profile-driven scheduling (cheap).
    Sharing,
}

/// A completed task (one inference) with its timing.
#[derive(Debug, Clone)]
pub struct TaskOutcome {
    pub task_key: TaskKey,
    pub task_id: TaskId,
    pub priority: Priority,
    /// When the invocation arrived (request time).
    pub arrival: SimTime,
    /// When its first kernel launch was issued.
    pub started: SimTime,
    /// When its last kernel finished on the device.
    pub finished: SimTime,
    pub kernels: u32,
    /// Stage the task ran in.
    pub stage: Stage,
}

impl TaskOutcome {
    /// Job completion time: arrival → last kernel completion (includes
    /// any wait, matching the paper's JCT definition).
    pub fn jct(&self) -> Duration {
        self.finished - self.arrival
    }
}

/// What the driver must do after feeding a kernel completion back to the
/// owning process.
#[derive(Debug, Clone)]
pub enum ProcessAction {
    /// Schedule the next kernel issue of the current task at this time
    /// (the completed kernel was a sync stall, or the run is serialized
    /// by measurement).
    IssueAt(SimTime),
    /// Nothing to do: the next issue was already pipelined (async
    /// launch-ahead) or is pending in the event queue.
    None,
    /// The current task finished. If the process has queued arrivals it
    /// is ready to start the next task (subject to mode rules, e.g. the
    /// exclusive-mode global lock).
    TaskCompleted(TaskOutcome),
}

/// Per-service CPU-side state machine.
pub struct ServiceProcess {
    pub service: Service,
    gen: TraceGenerator,
    /// Symbol-resolved kernel id per generator segment, computed once at
    /// construction (the resolver is deterministic). Issue-time launches
    /// clone these — an `Arc` refcount bump, never a fresh allocation
    /// (the old per-launch `resolve()` allocated an erased id on every
    /// launch under release-build symbol tables).
    seg_ids: Vec<crate::core::KernelId>,
    /// Interned handle per segment, assigned by [`ServiceProcess::bind`]
    /// at attach time ([`KernelHandle::UNBOUND`] until then).
    seg_handles: Vec<KernelHandle>,
    /// Interned service identity ([`TaskHandle::UNBOUND`] until bound).
    task_handle: TaskHandle,
    /// Extra CPU cost added before each launch (hook interception +
    /// scheduler round trip), set by the driver per mode.
    pub per_launch_overhead: Duration,
    stage: Stage,
    measurement_cfg: MeasurementConfig,
    recorder: Option<MeasurementRecorder>,

    // --- current task ---
    trace: KernelTrace,
    cursor: usize,
    task_id: TaskId,
    task_arrival: SimTime,
    task_started: SimTime,
    /// Completions observed for the current task. The task is done when
    /// this reaches the trace length — counting (not "final seq arrived")
    /// because preemption can re-queue a kernel behind its successors, so
    /// completion records may arrive out of seq order.
    done_in_task: u32,
    /// Latest device finish observed in the current task (the outcome's
    /// `finished` under out-of-order completion).
    task_last_finish: SimTime,
    run_records: Vec<KernelRecord>,
    active: bool,
    /// If the just-issued kernel is async, the CPU pacing delay after
    /// which the *next* launch should be issued once the current one is
    /// submitted to the device (launch-ahead pipelining).
    gate: Option<Duration>,
    /// True while an Issue event for trace position `cursor` is already
    /// scheduled (prevents double-issue from completion + pipeline).
    next_issue_scheduled: bool,

    // --- arrivals ---
    arrival_queue: VecDeque<SimTime>,
    next_task_seq: u64,
    /// Total tasks completed by this process.
    pub completed: u64,
}

impl ServiceProcess {
    pub fn new(
        service: Service,
        seed: u64,
        resolver: SymbolResolver,
        stage: Stage,
        measurement_cfg: MeasurementConfig,
    ) -> ServiceProcess {
        let spec = service.model.spec();
        let gen = TraceGenerator::new(&spec, seed);
        let seg_ids: Vec<crate::core::KernelId> = gen
            .ids()
            .iter()
            .map(|id| resolver.resolve(id).0)
            .collect();
        let seg_handles = vec![KernelHandle::UNBOUND; seg_ids.len()];
        let recorder = match stage {
            Stage::Measuring => Some(MeasurementRecorder::new(service.key.clone())),
            Stage::Sharing => None,
        };
        ServiceProcess {
            service,
            gen,
            seg_ids,
            seg_handles,
            task_handle: TaskHandle::UNBOUND,
            per_launch_overhead: Duration::ZERO,
            stage,
            measurement_cfg,
            recorder,
            trace: KernelTrace::default(),
            cursor: 0,
            task_id: TaskId(0),
            task_arrival: SimTime::ZERO,
            task_started: SimTime::ZERO,
            done_in_task: 0,
            task_last_finish: SimTime::ZERO,
            run_records: Vec::new(),
            active: false,
            gate: None,
            next_issue_scheduled: false,
            arrival_queue: VecDeque::new(),
            next_task_seq: 0,
            completed: 0,
        }
    }

    pub fn stage(&self) -> Stage {
        self.stage
    }

    /// Intern this process's identities: its task key's handle plus one
    /// kernel handle per trace segment. Called once at attach by the
    /// driver; after this every issued launch carries bound handles and
    /// the issue path does zero hashing.
    pub fn bind(&mut self, handle: TaskHandle, interner: &mut Interner) {
        self.task_handle = handle;
        for (slot, id) in self.seg_handles.iter_mut().zip(&self.seg_ids) {
            *slot = interner.intern_kernel(id);
        }
    }

    /// Interned service identity (unbound outside a sim).
    pub fn task_handle(&self) -> TaskHandle {
        self.task_handle
    }

    /// Inject gap interference: traces of *future* tasks sample their
    /// CPU-side think gaps scaled by `scale` (the in-flight task's
    /// trace is already drawn). Drives the drift experiment
    /// (DESIGN.md §9) through the driver's `GpuSim::inject_gap_scale`.
    pub fn set_gap_scale(&mut self, scale: f64) {
        self.gen.set_gap_scale(scale);
    }

    pub fn priority(&self) -> Priority {
        self.service.priority
    }

    pub fn key(&self) -> &TaskKey {
        &self.service.key
    }

    /// Is a task currently in flight?
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Are there arrivals waiting to start?
    pub fn has_queued_arrival(&self) -> bool {
        !self.arrival_queue.is_empty()
    }

    /// Record an arrival (the task does not start until
    /// [`ServiceProcess::try_start_task`] succeeds — mode rules decide when).
    pub fn enqueue_arrival(&mut self, now: SimTime) {
        self.arrival_queue.push_back(now);
    }

    /// Drop every queued (not-yet-started) arrival — a departing service
    /// abandons its backlog; the in-flight task (if any) still drains.
    /// Returns how many arrivals were dropped.
    pub fn clear_arrivals(&mut self) -> usize {
        let dropped = self.arrival_queue.len();
        self.arrival_queue.clear();
        dropped
    }

    /// Start the next queued task if the process is idle. Returns the
    /// time at which its first kernel should be issued.
    pub fn try_start_task(&mut self, now: SimTime) -> Option<SimTime> {
        if self.active {
            return None;
        }
        let arrival = self.arrival_queue.pop_front()?;
        self.trace = self.gen.next_trace();
        debug_assert!(!self.trace.is_empty(), "empty kernel trace");
        self.cursor = 0;
        self.task_id = TaskId(self.next_task_seq);
        self.next_task_seq += 1;
        self.task_arrival = arrival;
        self.task_started = now;
        self.done_in_task = 0;
        self.task_last_finish = SimTime::ZERO;
        self.run_records.clear();
        self.active = true;
        self.gate = None;
        self.next_issue_scheduled = true; // the caller schedules issue #0
        Some(now + self.per_launch_overhead)
    }

    /// Build the launch for the current cursor position. Called by the
    /// driver when the scheduled `IssueKernel` event fires. Advances the
    /// cursor.
    pub fn issue_next(&mut self, now: SimTime) -> KernelLaunch {
        debug_assert!(self.active, "issue_next on idle process");
        let tk = &self.trace.kernels[self.cursor];
        // Symbol resolution and interning happened once per segment (at
        // construction / bind); issuing is clones of `Arc`s plus copies.
        let seg = tk.seg as usize;
        let launch = KernelLaunch {
            task_key: self.service.key.clone(),
            task_handle: self.task_handle,
            task_id: self.task_id,
            kernel: self.seg_ids[seg].clone(),
            kernel_handle: self.seg_handles[seg],
            priority: self.service.priority,
            seq: self.cursor as u32,
            true_duration: tk.exec,
            issued_at: now,
        };
        // Decide how the *next* launch is gated. Async kernels pipeline:
        // the CPU spends only the pacing gap and launches ahead. Sync
        // kernels (and every kernel under measurement, where per-kernel
        // timing events serialize the pipeline) wait for completion.
        let has_next = self.cursor + 1 < self.trace.len();
        self.gate = if has_next && !tk.sync && self.stage != Stage::Measuring {
            Some(tk.gap_after + self.per_launch_overhead)
        } else {
            None
        };
        self.cursor += 1;
        self.next_issue_scheduled = false;
        launch
    }

    /// The most recently issued kernel was submitted to the device at
    /// `submit_time` (immediately for direct launches; at release time
    /// for launches the scheduler held). If the launch was async-gated,
    /// returns when the next issue should fire.
    pub fn on_submitted(&mut self, submit_time: SimTime) -> Option<SimTime> {
        if !self.active || self.next_issue_scheduled {
            return None;
        }
        let delay = self.gate.take()?;
        self.next_issue_scheduled = true;
        Some(submit_time + delay)
    }

    /// Feed back the completion record of this process's kernel `seq`.
    /// Returns what to do next.
    pub fn on_kernel_done(&mut self, record: KernelRecord, now: SimTime) -> ProcessAction {
        debug_assert!(self.active);
        debug_assert_eq!(record.task_id, self.task_id, "stale record routed to process");
        let seq = record.seq as usize;
        let exec = record.exec_time();
        self.done_in_task += 1;
        self.task_last_finish = self.task_last_finish.max(record.finished_at);
        if self.stage == Stage::Measuring {
            self.run_records.push(record);
        }

        if self.done_in_task as usize == self.trace.len() {
            // Task complete. Count-based, not "final seq arrived":
            // preemption can deliver the final seq before a re-queued
            // straggler, and the task only ends once every kernel landed.
            let outcome = TaskOutcome {
                task_key: self.service.key.clone(),
                task_id: self.task_id,
                priority: self.service.priority,
                arrival: self.task_arrival,
                started: self.task_started,
                finished: self.task_last_finish,
                kernels: self.trace.len() as u32,
                stage: self.stage,
            };
            if self.stage == Stage::Measuring {
                let records = std::mem::take(&mut self.run_records);
                if let Some(rec) = self.recorder.as_mut() {
                    rec.ingest_run(&records);
                }
            }
            self.active = false;
            self.gate = None;
            self.next_issue_scheduled = false;
            self.completed += 1;
            return ProcessAction::TaskCompleted(outcome);
        }

        if seq + 1 < self.trace.len() {
            if seq + 1 < self.cursor || self.next_issue_scheduled {
                // The next launch was already issued (pipelined ahead) or
                // its Issue event is pending.
                return ProcessAction::None;
            }
            debug_assert_eq!(seq + 1, self.cursor, "completion raced past cursor");
            // Sync kernel (or measurement serialization): the CPU resumes
            // now, spends the post-processing gap (plus measurement +
            // hook costs) and issues the next launch.
            let mut delay = self.trace.kernels[seq].gap_after + self.per_launch_overhead;
            if self.stage == Stage::Measuring {
                delay += self.measurement_cfg.per_kernel_overhead(exec);
            }
            self.next_issue_scheduled = true;
            ProcessAction::IssueAt(now + delay)
        } else {
            // The final-seq record arrived while an earlier (preempted and
            // re-queued) kernel is still in flight; the straggler's
            // completion fires TaskCompleted above.
            ProcessAction::None
        }
    }

    /// Whether the measurement recorder has gathered enough runs.
    pub fn measurement_complete(&self) -> bool {
        self.recorder
            .as_ref()
            .is_some_and(|r| r.is_complete(&self.measurement_cfg))
    }

    /// Transition measuring → sharing, yielding the gathered profile.
    pub fn finish_measurement(&mut self) -> Option<TaskProfile> {
        let recorder = self.recorder.take()?;
        self.stage = Stage::Sharing;
        Some(recorder.finish())
    }

    /// Remaining kernels in the current task (0 when idle).
    pub fn remaining_kernels(&self) -> usize {
        if self.active {
            self.trace.len() - self.cursor
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::LaunchSource;
    use crate::profile::SymbolTableModel;
    use crate::workload::{InvocationPattern, ModelKind};

    fn proc(stage: Stage) -> ServiceProcess {
        let svc = Service::new(
            ModelKind::Alexnet,
            Priority::P0,
            InvocationPattern::BackToBack { count: 2 },
        );
        ServiceProcess::new(
            svc,
            1,
            SymbolResolver::new(SymbolTableModel::default()),
            stage,
            MeasurementConfig { runs: 1, ..Default::default() },
        )
    }

    /// Drive one full task through a fake serial device (each kernel
    /// starts the moment the previous finished or the launch arrives).
    fn run_task(p: &mut ServiceProcess, start: SimTime) -> TaskOutcome {
        p.enqueue_arrival(start);
        let mut issue_at = p.try_start_task(start).unwrap();
        let mut device_free = start;
        loop {
            let launch = p.issue_next(issue_at);
            let begin = issue_at.max(device_free);
            let rec = KernelRecord {
                task_key: launch.task_key.clone(),
                task_handle: launch.task_handle,
                task_id: launch.task_id,
                kernel: launch.kernel.clone(),
                kernel_handle: launch.kernel_handle,
                priority: launch.priority,
                seq: launch.seq,
                source: LaunchSource::Direct,
                issued_at: issue_at,
                started_at: begin,
                finished_at: begin + launch.true_duration,
            };
            device_free = rec.finished_at;
            // Pipelined (async) next issue?
            let pipelined = p.on_submitted(issue_at);
            let done_at = rec.finished_at;
            match p.on_kernel_done(rec, done_at) {
                ProcessAction::IssueAt(next) => issue_at = next,
                ProcessAction::None => {
                    issue_at = pipelined.expect("None action implies pipelined issue");
                }
                ProcessAction::TaskCompleted(outcome) => return outcome,
            }
        }
    }

    #[test]
    fn pipelined_jct_approximates_exec_plus_stalls() {
        let mut p = proc(Stage::Sharing);
        let spec = ModelKind::Alexnet.spec();
        let out = run_task(&mut p, SimTime::ZERO);
        assert_eq!(out.kernels, spec.kernel_count());
        // Serial fake device, pipelined launches: JCT ≈ exec + sync gaps.
        let jct_ms = out.jct().as_millis_f64();
        let expect = spec.mean_jct().as_millis_f64();
        assert!(
            (jct_ms - expect).abs() / expect < 0.35,
            "jct {jct_ms} vs {expect}"
        );
        assert!(!p.is_active());
        assert_eq!(p.completed, 1);
    }

    #[test]
    fn measuring_stage_inflates_jct_and_builds_profile() {
        let mut sharing = proc(Stage::Sharing);
        let mut measuring = proc(Stage::Measuring);
        let jct_s = run_task(&mut sharing, SimTime::ZERO).jct();
        let jct_m = run_task(&mut measuring, SimTime::ZERO).jct();
        let overhead = jct_m.as_millis_f64() / jct_s.as_millis_f64();
        // Paper: measuring costs 20–80% extra (serialization + events).
        assert!(overhead > 1.15, "measuring overhead ratio {overhead}");
        assert!(overhead < 2.2, "measuring overhead ratio {overhead}");

        assert!(measuring.measurement_complete());
        let profile = measuring.finish_measurement().unwrap();
        assert_eq!(measuring.stage(), Stage::Sharing);
        assert!(profile.is_ready(1));
        assert!(profile.num_unique() > 0);
    }

    #[test]
    fn arrivals_queue_when_busy() {
        let mut p = proc(Stage::Sharing);
        p.enqueue_arrival(SimTime::ZERO);
        p.enqueue_arrival(SimTime(10));
        assert!(p.try_start_task(SimTime::ZERO).is_some());
        // Busy: second task cannot start yet.
        assert!(p.try_start_task(SimTime(20)).is_none());
        assert!(p.has_queued_arrival());
    }

    #[test]
    fn task_ids_are_monotonic() {
        let mut p = proc(Stage::Sharing);
        let o1 = run_task(&mut p, SimTime::ZERO);
        let o2 = run_task(&mut p, SimTime(1_000_000));
        assert_eq!(o1.task_id, TaskId(0));
        assert_eq!(o2.task_id, TaskId(1));
        // Second arrival's JCT measured from its own arrival.
        assert_eq!(o2.arrival, SimTime(1_000_000));
    }
}
