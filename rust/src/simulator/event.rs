//! Deterministic discrete-event queue.
//!
//! The queue pops in strict `(time, seq)` order — `seq` is a
//! monotonically increasing insertion counter, so simultaneous events
//! pop in insertion order and every run with the same seed replays
//! identically (ADR-001). Since ADR-003 the storage is a calendar-queue
//! [`CalendarWheel`] (O(1) amortized push/pop for the near-future dense
//! band, heap overflow ring for far-future events) instead of one big
//! binary heap; the pop order is bit-identical to the heap's, pinned by
//! the differential test in `tests/sim_core.rs`.
//!
//! [`Event`] is a small `Copy` enum: a completed kernel's payload lives
//! in the per-sim [`KernelArena`](super::KernelArena) and `KernelDone`
//! carries only its [`RecordSlot`] handle.

use super::arena::RecordSlot;
use super::wheel::CalendarWheel;
use crate::core::SimTime;

/// Events driving the simulation loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A new task (invocation) of service `svc` arrives.
    TaskArrival { svc: usize },
    /// Service `svc`'s CPU side issues its next kernel launch.
    IssueKernel { svc: usize },
    /// A kernel previously submitted to the device finishes executing;
    /// its [`KernelRecord`](crate::core::KernelRecord) is parked in the
    /// sim's arena at `rec`.
    KernelDone { svc: usize, rec: RecordSlot },
}

/// Calendar-queue of timestamped events with deterministic tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    wheel: CalendarWheel<Event>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `event` at `time`.
    pub fn push(&mut self, time: SimTime, event: Event) {
        self.wheel.push(time, event);
    }

    /// Pop the earliest event (ties: insertion order).
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.wheel.pop()
    }

    /// Pop the earliest event only if it is at or before `bound`; the
    /// wheel cursor never advances past the bound, so interleaved
    /// pushes at the bound (mid-run attach) stay on the fast path.
    pub fn pop_if_before(&mut self, bound: SimTime) -> Option<(SimTime, Event)> {
        self.wheel.pop_if_before(bound)
    }

    /// Time of the next event without popping. (Positions the wheel
    /// cursor, hence `&mut`.)
    pub fn peek_time(&mut self) -> Option<SimTime> {
        self.wheel.peek_time()
    }

    /// Reset to empty without releasing bucket/heap storage — the
    /// multi-run reuse path (`SimScratch`): fig13–21 sweeps and `fikit
    /// drift` rebuild sims per run but pay the queue's allocation cost
    /// once.
    pub fn clear(&mut self) {
        self.wheel.clear();
    }

    pub fn len(&self) -> usize {
        self.wheel.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wheel.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(SimTime(30), Event::TaskArrival { svc: 3 });
        q.push(SimTime(10), Event::TaskArrival { svc: 1 });
        q.push(SimTime(10), Event::IssueKernel { svc: 2 });
        q.push(SimTime(20), Event::IssueKernel { svc: 9 });

        assert_eq!(q.peek_time(), Some(SimTime(10)));
        let (t1, e1) = q.pop().unwrap();
        assert_eq!((t1, e1), (SimTime(10), Event::TaskArrival { svc: 1 }));
        // Same-time events pop in insertion order.
        let (t2, e2) = q.pop().unwrap();
        assert_eq!((t2, e2), (SimTime(10), Event::IssueKernel { svc: 2 }));
        assert_eq!(q.pop().unwrap().0, SimTime(20));
        assert_eq!(q.pop().unwrap().0, SimTime(30));
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn clear_reuses_queue_across_runs() {
        let mut q = EventQueue::new();
        for i in 0..64u64 {
            q.push(SimTime(i * 1_000_000), Event::IssueKernel { svc: 0 });
        }
        q.clear();
        assert!(q.is_empty());
        q.push(SimTime(5), Event::TaskArrival { svc: 7 });
        assert_eq!(
            q.pop(),
            Some((SimTime(5), Event::TaskArrival { svc: 7 }))
        );
    }
}
