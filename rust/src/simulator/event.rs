//! Deterministic discrete-event queue.
//!
//! A binary heap keyed on `(time, seq)` — `seq` is a monotonically
//! increasing insertion counter, so simultaneous events pop in insertion
//! order and every run with the same seed replays identically.

use crate::core::{KernelRecord, SimTime};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Events driving the simulation loop.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A new task (invocation) of service `svc` arrives.
    TaskArrival { svc: usize },
    /// Service `svc`'s CPU side issues its next kernel launch.
    IssueKernel { svc: usize },
    /// A kernel previously submitted to the device finishes executing.
    KernelDone { svc: usize, record: KernelRecord },
}

/// Min-heap of timestamped events with deterministic tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

#[derive(Debug)]
struct Entry {
    time: SimTime,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `event` at `time`.
    pub fn push(&mut self, time: SimTime, event: Event) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { time, seq, event }));
    }

    /// Pop the earliest event (ties: insertion order).
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.heap.pop().map(|Reverse(e)| (e.time, e.event))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(SimTime(30), Event::TaskArrival { svc: 3 });
        q.push(SimTime(10), Event::TaskArrival { svc: 1 });
        q.push(SimTime(10), Event::IssueKernel { svc: 2 });
        q.push(SimTime(20), Event::IssueKernel { svc: 9 });

        assert_eq!(q.peek_time(), Some(SimTime(10)));
        let (t1, e1) = q.pop().unwrap();
        assert_eq!((t1, e1), (SimTime(10), Event::TaskArrival { svc: 1 }));
        // Same-time events pop in insertion order.
        let (t2, e2) = q.pop().unwrap();
        assert_eq!((t2, e2), (SimTime(10), Event::IssueKernel { svc: 2 }));
        assert_eq!(q.pop().unwrap().0, SimTime(20));
        assert_eq!(q.pop().unwrap().0, SimTime(30));
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }
}
