//! Pluggable hardware concurrency backends (DESIGN.md §6, ADR-006).
//!
//! The paper's testbed serializes co-resident kernels through one FIFO
//! hardware queue, but real deployments choose a concurrency mechanism —
//! time-sliced streams, MPS spatial sharing, or MIG partitioning — and
//! the *magnitude* of cross-tenant interference is a function of that
//! choice (Gilman & Walls, arXiv 2110.00459). [`ConcurrencyBackend`]
//! makes the mechanism an explicit seam on
//! [`DeviceConfig`](super::DeviceConfig): the default reproduces the
//! pre-seam device byte for byte, the other two give the interference
//! model (`cluster/compat.rs`) a hardware story to learn against.

use crate::core::Error;
use std::fmt;
use std::str::FromStr;

/// Default per-co-resident throughput dilation for [`ConcurrencyBackend::MpsSpatial`]
/// when the CLI flag names the backend without a parameter (`--backend mps`).
/// Each concurrently running kernel stretches a newcomer's execution by
/// this fraction — the mid-range of published MPS co-location slowdowns.
pub const DEFAULT_MPS_DILATION: f64 = 0.15;

/// Default slice count for a bare `--backend mig`.
pub const DEFAULT_MIG_SLICES: u32 = 2;

/// How the simulated device runs kernels from co-resident tenants
/// (DESIGN.md §6 "Concurrency backends").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConcurrencyBackend {
    /// One FIFO hardware queue, non-preemptive, exactly one kernel at a
    /// time — the paper's testbed model and the default. Reports are
    /// byte-identical to the pre-backend-seam simulator.
    TimeSliced,
    /// MPS-style spatial sharing: co-resident kernels overlap instead of
    /// queueing, and each kernel already running when a new one starts
    /// dilates the newcomer's execution time by `dilation` (throughput
    /// contention on SMs/L2/HBM). `dilation = 0` is perfect overlap.
    MpsSpatial {
        /// Fractional execution-time stretch per concurrently running
        /// kernel: `exec × (1 + dilation × co_resident)`.
        dilation: f64,
    },
    /// MIG-style hard partitioning into `slices` equal instances:
    /// kernels on different slices overlap freely, each slice has
    /// `1/slices` of the device's compute (execution times scale by
    /// `slices`), and a busy slice queues FIFO. Generalizes
    /// [`DeviceConfig::mig_instance`](super::DeviceConfig::mig_instance),
    /// which models renting a *single* slice of a partitioned device.
    MigPartition {
        /// Number of equal hard slices (≥ 1).
        slices: u32,
    },
}

impl Default for ConcurrencyBackend {
    fn default() -> ConcurrencyBackend {
        ConcurrencyBackend::TimeSliced
    }
}

impl ConcurrencyBackend {
    /// An MPS backend with the default dilation.
    pub fn mps() -> ConcurrencyBackend {
        ConcurrencyBackend::MpsSpatial {
            dilation: DEFAULT_MPS_DILATION,
        }
    }

    /// A MIG backend with `slices` hard partitions (≥ 1).
    pub fn mig(slices: u32) -> ConcurrencyBackend {
        assert!(slices >= 1, "bad MIG slice count");
        ConcurrencyBackend::MigPartition { slices }
    }

    /// Stable short name (the config/CLI token, without parameters).
    pub fn name(&self) -> &'static str {
        match self {
            ConcurrencyBackend::TimeSliced => "timesliced",
            ConcurrencyBackend::MpsSpatial { .. } => "mps",
            ConcurrencyBackend::MigPartition { .. } => "mig",
        }
    }
}

impl fmt::Display for ConcurrencyBackend {
    /// Round-trippable token: `timesliced`, `mps:<dilation>`,
    /// `mig:<slices>` — what `ExperimentConfig::to_json` persists.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConcurrencyBackend::TimeSliced => write!(f, "timesliced"),
            ConcurrencyBackend::MpsSpatial { dilation } => write!(f, "mps:{dilation}"),
            ConcurrencyBackend::MigPartition { slices } => write!(f, "mig:{slices}"),
        }
    }
}

impl FromStr for ConcurrencyBackend {
    type Err = Error;

    fn from_str(s: &str) -> Result<ConcurrencyBackend, Error> {
        let (kind, param) = match s.split_once(':') {
            Some((k, p)) => (k, Some(p)),
            None => (s, None),
        };
        match kind {
            "timesliced" | "fifo" => match param {
                None => Ok(ConcurrencyBackend::TimeSliced),
                Some(p) => Err(Error::Config(format!(
                    "backend 'timesliced' takes no parameter (got ':{p}')"
                ))),
            },
            "mps" => {
                let dilation = match param {
                    None => DEFAULT_MPS_DILATION,
                    Some(p) => p.parse::<f64>().map_err(|_| {
                        Error::Config(format!("bad MPS dilation '{p}' (want a float)"))
                    })?,
                };
                if !(dilation >= 0.0) {
                    return Err(Error::Config(format!(
                        "MPS dilation must be >= 0 (got {dilation})"
                    )));
                }
                Ok(ConcurrencyBackend::MpsSpatial { dilation })
            }
            "mig" => {
                let slices = match param {
                    None => DEFAULT_MIG_SLICES,
                    Some(p) => p.parse::<u32>().map_err(|_| {
                        Error::Config(format!("bad MIG slice count '{p}' (want an integer)"))
                    })?,
                };
                if slices == 0 {
                    return Err(Error::Config("MIG needs at least one slice".into()));
                }
                Ok(ConcurrencyBackend::MigPartition { slices })
            }
            other => Err(Error::Config(format!(
                "unknown concurrency backend '{other}' (want timesliced, mps[:dilation] \
                 or mig[:slices])"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_round_trip() {
        for b in [
            ConcurrencyBackend::TimeSliced,
            ConcurrencyBackend::MpsSpatial { dilation: 0.25 },
            ConcurrencyBackend::MigPartition { slices: 7 },
        ] {
            let token = b.to_string();
            assert_eq!(token.parse::<ConcurrencyBackend>().unwrap(), b);
        }
    }

    #[test]
    fn bare_tokens_get_defaults() {
        assert_eq!(
            "mps".parse::<ConcurrencyBackend>().unwrap(),
            ConcurrencyBackend::MpsSpatial {
                dilation: DEFAULT_MPS_DILATION
            }
        );
        assert_eq!(
            "mig".parse::<ConcurrencyBackend>().unwrap(),
            ConcurrencyBackend::MigPartition {
                slices: DEFAULT_MIG_SLICES
            }
        );
        assert_eq!(
            "timesliced".parse::<ConcurrencyBackend>().unwrap(),
            ConcurrencyBackend::TimeSliced
        );
    }

    #[test]
    fn bad_tokens_are_rejected() {
        assert!("nvlink".parse::<ConcurrencyBackend>().is_err());
        assert!("mps:fast".parse::<ConcurrencyBackend>().is_err());
        assert!("mps:-0.5".parse::<ConcurrencyBackend>().is_err());
        assert!("mig:0".parse::<ConcurrencyBackend>().is_err());
        assert!("timesliced:2".parse::<ConcurrencyBackend>().is_err());
    }
}
