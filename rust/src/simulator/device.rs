//! The single-GPU device model: non-preemptive kernel execution behind a
//! pluggable [`ConcurrencyBackend`], full busy/idle accounting.
//!
//! Under every backend a kernel's `(start, finish)` are fully determined
//! the moment it is submitted — `TimeSliced` queues FIFO behind the
//! device (`start = max(now + launch_latency, device_free)`, exactly one
//! kernel at a time), `MpsSpatial` starts at readiness with
//! occupancy-dilated execution, `MigPartition` queues FIFO per hard
//! slice. [`SimDevice::submit`] therefore returns the finished
//! [`KernelRecord`] synchronously; the driver parks it in the sim's
//! [`KernelArena`](super::KernelArena) and turns `finished_at` into a
//! completion event carrying the slot handle (ADR-003 — events
//! themselves stay small and `Copy`).

use super::backend::ConcurrencyBackend;
use crate::core::{Duration, KernelLaunch, KernelRecord, LaunchSource, SimTime};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Hardware/driver timing parameters.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// Time from a launch leaving the CPU to the kernel being runnable on
    /// the device (driver + PCIe + dispatch). The paper cites typical
    /// launch costs of 5–30 µs; NVIDIA's own figure is ~5 µs.
    pub launch_latency: Duration,
    /// Compute throughput of this device relative to the full GPU the
    /// workload traces were calibrated on. Models a **MIG instance**
    /// (paper §2.1: "the scheduling design of this paper can apply to a
    /// single GPU instance under MIG partitioning") — a 3/7 A100 slice
    /// is ≈0.43. Kernel execution times scale by 1/compute_scale;
    /// CPU-side gaps are unaffected (they are host work).
    pub compute_scale: f64,
    /// How co-resident kernels share the device (DESIGN.md §6
    /// "Concurrency backends"). The default, `TimeSliced`, is the
    /// paper's single-FIFO-queue model and reproduces pre-seam reports
    /// byte for byte.
    pub backend: ConcurrencyBackend,
}

impl Default for DeviceConfig {
    fn default() -> DeviceConfig {
        DeviceConfig {
            launch_latency: Duration::from_micros(5),
            compute_scale: 1.0,
            backend: ConcurrencyBackend::TimeSliced,
        }
    }
}

impl DeviceConfig {
    /// A MIG instance with the given compute fraction (0 < f ≤ 1).
    pub fn mig_instance(fraction: f64) -> DeviceConfig {
        assert!(fraction > 0.0 && fraction <= 1.0, "bad MIG fraction");
        DeviceConfig {
            compute_scale: fraction,
            ..DeviceConfig::default()
        }
    }
}

/// Aggregate device accounting for a simulation run.
#[derive(Debug, Clone, Default)]
pub struct DeviceStats {
    /// Total kernels executed.
    pub kernels: u64,
    /// Σ kernel execution time (device busy).
    pub busy: Duration,
    /// Kernels submitted via gap filling.
    pub fill_kernels: u64,
    /// Busy time contributed by gap-fill kernels.
    pub fill_busy: Duration,
    /// Time of the last kernel completion.
    pub last_finish: SimTime,
}

impl DeviceStats {
    /// Device utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon.nanos() == 0 {
            0.0
        } else {
            self.busy.nanos() as f64 / horizon.nanos() as f64
        }
    }
}

/// The simulated GPU.
#[derive(Debug)]
pub struct SimDevice {
    cfg: DeviceConfig,
    /// Time at which the device finishes everything currently queued.
    free_at: SimTime,
    stats: DeviceStats,
    /// Completion min-heap: `(finish_time, is_fill)` of kernels not yet
    /// finished — answers "how many kernels are pending ahead of time t"
    /// (feedback overhead-2 accounting). Pruning pops expired heads in
    /// O(log n) each instead of the old O(n) retain-scan per submit.
    in_flight: BinaryHeap<Reverse<(SimTime, bool)>>,
    /// Pending gap-fill kernels (subset of `in_flight`), maintained
    /// incrementally so `pending_fills` needs no iteration.
    fills_in_flight: usize,
    /// Lazy-deletion multiset for [`SimDevice::preempt`]: the wheel and
    /// this heap have no random removal, so a preempted kernel's original
    /// completion entry stays in `in_flight` and its twin is recorded
    /// here; `prune` drops matching pairs without touching the counters.
    /// Empty for the entire run under `PreemptionPolicy::None`, keeping
    /// the no-preemption arithmetic byte-identical.
    cancelled: BinaryHeap<Reverse<(SimTime, bool)>>,
    /// Per-slice drain times for [`ConcurrencyBackend::MigPartition`]
    /// (empty under the other backends): each hard slice is its own
    /// little FIFO device.
    slice_free: Vec<SimTime>,
}

impl SimDevice {
    pub fn new(cfg: DeviceConfig) -> SimDevice {
        let slice_free = match cfg.backend {
            ConcurrencyBackend::MigPartition { slices } => {
                vec![SimTime::ZERO; slices.max(1) as usize]
            }
            _ => Vec::new(),
        };
        SimDevice {
            cfg,
            free_at: SimTime::ZERO,
            stats: DeviceStats::default(),
            in_flight: BinaryHeap::with_capacity(8),
            fills_in_flight: 0,
            cancelled: BinaryHeap::new(),
            slice_free,
        }
    }

    pub fn config(&self) -> &DeviceConfig {
        &self.cfg
    }

    /// Submit a kernel launch at CPU time `now`, consuming it. Returns
    /// the completed execution record (non-preemptive ⇒ deterministic at
    /// submission under every backend). Taking the launch by value lets
    /// the record inherit its `task_key`/`kernel` by move — the submit
    /// path does not even bump `Arc` refcounts.
    pub fn submit(&mut self, launch: KernelLaunch, now: SimTime, source: LaunchSource) -> KernelRecord {
        let ready = now + self.cfg.launch_latency;
        // MIG slice: fewer SMs → kernels take proportionally longer.
        let base = if self.cfg.compute_scale >= 1.0 {
            launch.true_duration
        } else {
            launch.true_duration.scale(1.0 / self.cfg.compute_scale)
        };
        let (start, exec) = match self.cfg.backend {
            // The paper's model: one FIFO hardware queue, one kernel at
            // a time. This arm is the pre-seam arithmetic unchanged.
            ConcurrencyBackend::TimeSliced => (ready.max(self.free_at), base),
            // Spatial sharing: no queueing behind co-residents — the
            // kernel starts at readiness, stretched by every kernel
            // still running then (contention, not serialization).
            ConcurrencyBackend::MpsSpatial { dilation } => {
                // Cancelled (preempted) entries are still in `in_flight`
                // awaiting their lazy-deletion pop; they no longer run,
                // so they must not dilate new arrivals.
                let co = self
                    .in_flight
                    .iter()
                    .filter(|Reverse((finish, _))| *finish > ready)
                    .count()
                    - self
                        .cancelled
                        .iter()
                        .filter(|Reverse((finish, _))| *finish > ready)
                        .count();
                (ready, base.scale(1.0 + dilation * co as f64))
            }
            // Hard partitioning: FIFO per slice, each slice at 1/slices
            // of the device's compute. The earliest-free slice wins;
            // ties go to the lowest index (deterministic).
            ConcurrencyBackend::MigPartition { .. } => {
                let slices = self.slice_free.len();
                let mut best = 0;
                for i in 1..slices {
                    if self.slice_free[i] < self.slice_free[best] {
                        best = i;
                    }
                }
                let start = ready.max(self.slice_free[best]);
                let exec = base.scale(slices as f64);
                self.slice_free[best] = start + exec;
                (start, exec)
            }
        };
        let finish = start + exec;
        // Under TimeSliced `finish >= free_at` always holds, so the max
        // is exactly the old `free_at = finish`; the overlap backends
        // may complete out of submission order.
        self.free_at = self.free_at.max(finish);

        self.stats.kernels += 1;
        self.stats.busy += exec;
        let is_fill = source == LaunchSource::GapFill;
        if is_fill {
            self.stats.fill_kernels += 1;
            self.stats.fill_busy += exec;
        }
        self.stats.last_finish = self.stats.last_finish.max(finish);

        self.prune(now);
        self.in_flight.push(Reverse((finish, is_fill)));
        if is_fill {
            self.fills_in_flight += 1;
        }

        KernelRecord {
            task_key: launch.task_key,
            task_handle: launch.task_handle,
            task_id: launch.task_id,
            kernel: launch.kernel,
            kernel_handle: launch.kernel_handle,
            priority: launch.priority,
            seq: launch.seq,
            source,
            issued_at: now,
            started_at: start,
            finished_at: finish,
        }
    }

    fn prune(&mut self, now: SimTime) {
        while let Some(&Reverse((finish, is_fill))) = self.in_flight.peek() {
            if finish > now {
                break;
            }
            // A cancelled completion: drop the tombstone pair without
            // touching the counters — `preempt` already adjusted them.
            // (Identical tuples are interchangeable; cancelling "one
            // occurrence" is exact multiset deletion.)
            if self
                .cancelled
                .peek()
                .is_some_and(|&Reverse(entry)| entry == (finish, is_fill))
            {
                self.cancelled.pop();
                self.in_flight.pop();
                continue;
            }
            self.in_flight.pop();
            if is_fill {
                self.fills_in_flight -= 1;
            }
        }
    }

    /// Cancel (`cut_at == started_at`) or shorten an in-flight kernel,
    /// rewinding the backend tail it occupies to `cut_at + penalty` —
    /// `penalty` is the modeled preemption cost, charged as dead time
    /// (never as busy). Returns `false` without touching anything when
    /// the backend cannot reclaim the kernel: the cut is outside
    /// `[started_at, finished_at)`, or the kernel is not the reclaimable
    /// tail of its FIFO (TimeSliced) / slice (MIG). The caller re-queues
    /// the remnant and cancels the arena slot; the stale `KernelDone`
    /// event is absorbed by `take_if_live` when it pops.
    pub fn preempt(&mut self, record: &KernelRecord, cut_at: SimTime, penalty: Duration) -> bool {
        if cut_at < record.started_at || cut_at >= record.finished_at {
            return false;
        }
        match self.cfg.backend {
            ConcurrencyBackend::TimeSliced => {
                // Only the FIFO tail is reclaimable: anything queued
                // behind already has a committed start time.
                if record.finished_at != self.free_at {
                    return false;
                }
                self.free_at = cut_at + penalty;
            }
            // Spatial sharing has no queue to rewind — nothing waits on
            // this kernel; the interruption still charges its dead time.
            ConcurrencyBackend::MpsSpatial { .. } => {
                self.free_at = self.free_at.max(cut_at + penalty);
            }
            ConcurrencyBackend::MigPartition { .. } => {
                let Some(slice) =
                    self.slice_free.iter().position(|&f| f == record.finished_at)
                else {
                    return false;
                };
                self.slice_free[slice] = cut_at + penalty;
                // Drain time of everything still queued = slowest slice.
                self.free_at = self
                    .slice_free
                    .iter()
                    .copied()
                    .max()
                    .unwrap_or(SimTime::ZERO);
            }
        }

        let refund = record.finished_at - cut_at;
        self.stats.busy -= refund;
        let is_fill = record.source == LaunchSource::GapFill;
        if is_fill {
            self.stats.fill_busy -= refund;
        }
        self.cancelled.push(Reverse((record.finished_at, is_fill)));
        if cut_at == record.started_at {
            // Evicted before it ever ran: roll the launch back entirely.
            self.stats.kernels -= 1;
            if is_fill {
                self.stats.fill_kernels -= 1;
                self.fills_in_flight -= 1;
            }
        } else {
            // The executed prefix stays on the device until the cut.
            self.in_flight.push(Reverse((cut_at, is_fill)));
        }
        true
    }

    /// Where a launch issued at `now` would start under the current
    /// backlog — the preempt decision's "would the holder miss its gap"
    /// probe. Pure; mirrors the `submit` start arithmetic per backend.
    pub fn projected_start(&self, now: SimTime) -> SimTime {
        let ready = now + self.cfg.launch_latency;
        match self.cfg.backend {
            ConcurrencyBackend::TimeSliced => ready.max(self.free_at),
            ConcurrencyBackend::MpsSpatial { .. } => ready,
            ConcurrencyBackend::MigPartition { .. } => {
                let earliest = self
                    .slice_free
                    .iter()
                    .copied()
                    .min()
                    .unwrap_or(SimTime::ZERO);
                ready.max(earliest)
            }
        }
    }

    /// Time at which the device will have drained everything submitted.
    pub fn free_at(&self) -> SimTime {
        self.free_at
    }

    /// Remaining backlog as seen at `now` (0 if idle).
    pub fn backlog(&self, now: SimTime) -> Duration {
        self.free_at - now
    }

    /// Is the device idle at `now`?
    pub fn is_idle(&self, now: SimTime) -> bool {
        self.free_at <= now
    }

    /// Number of kernels still pending (queued or running) at `now`.
    pub fn pending(&mut self, now: SimTime) -> usize {
        self.prune(now);
        // Tombstoned (preempted) entries await lazy deletion but no
        // longer represent pending work.
        self.in_flight.len() - self.cancelled.len()
    }

    /// Number of pending *fill* kernels at `now` — the un-recallable
    /// kernels of the paper's "overhead 2" (Fig 12).
    pub fn pending_fills(&mut self, now: SimTime) -> usize {
        self.prune(now);
        self.fills_in_flight
    }

    pub fn stats(&self) -> &DeviceStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelHandle, KernelId, Priority, TaskHandle, TaskId, TaskKey};

    fn launch(dur_us: u64, at: SimTime) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new("svc"),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(0),
            kernel: KernelId::new("k", Dim3::x(1), Dim3::x(32)),
            kernel_handle: KernelHandle::UNBOUND,
            priority: Priority::P0,
            seq: 0,
            true_duration: Duration::from_micros(dur_us),
            issued_at: at,
        }
    }

    fn dev() -> SimDevice {
        SimDevice::new(DeviceConfig {
            launch_latency: Duration::from_micros(5),
            compute_scale: 1.0,
            ..DeviceConfig::default()
        })
    }

    #[test]
    fn fifo_back_to_back_execution() {
        let mut d = dev();
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        assert_eq!(r1.started_at, SimTime(5_000)); // launch latency
        assert_eq!(r1.finished_at, SimTime(105_000));

        // Second kernel submitted while first still running: queues FIFO.
        let r2 = d.submit(launch(50, t0), t0, LaunchSource::Direct);
        assert_eq!(r2.started_at, SimTime(105_000));
        assert_eq!(r2.finished_at, SimTime(155_000));
        assert_eq!(r2.queue_delay(), Duration::from_micros(105));

        assert_eq!(d.stats().kernels, 2);
        assert_eq!(d.stats().busy, Duration::from_micros(150));
    }

    #[test]
    fn idle_gap_between_late_submissions() {
        let mut d = dev();
        let r1 = d.submit(launch(100, SimTime::ZERO), SimTime::ZERO, LaunchSource::Direct);
        // Device is idle once the first kernel drains.
        assert!(d.is_idle(SimTime(r1.finished_at.nanos() + 1_000)));
        // Next launch issued 80us after finish — device idled in between.
        let t2 = r1.finished_at + Duration::from_micros(80);
        let r2 = d.submit(launch(100, t2), t2, LaunchSource::Direct);
        assert_eq!(r2.started_at, t2 + Duration::from_micros(5));
        assert!(!d.is_idle(t2));
    }

    #[test]
    fn pending_and_fill_accounting() {
        let mut d = dev();
        let t0 = SimTime::ZERO;
        d.submit(launch(100, t0), t0, LaunchSource::Direct);
        d.submit(launch(100, t0), t0, LaunchSource::GapFill);
        d.submit(launch(100, t0), t0, LaunchSource::GapFill);
        assert_eq!(d.pending(SimTime(10_000)), 3);
        assert_eq!(d.pending_fills(SimTime(10_000)), 2);
        // After the first two finish (5us + 200us), one fill remains.
        assert_eq!(d.pending(SimTime(210_000)), 1);
        assert_eq!(d.pending_fills(SimTime(210_000)), 1);
        assert_eq!(d.pending(SimTime(400_000)), 0);
        assert_eq!(d.stats().fill_kernels, 2);
        assert_eq!(d.stats().fill_busy, Duration::from_micros(200));
    }

    #[test]
    fn mig_instance_scales_execution_not_gaps() {
        // A half-GPU MIG slice doubles kernel execution times.
        let mut d = SimDevice::new(DeviceConfig {
            launch_latency: Duration::from_micros(5),
            ..DeviceConfig::mig_instance(0.5)
        });
        let r = d.submit(launch(100, SimTime::ZERO), SimTime::ZERO, LaunchSource::Direct);
        assert_eq!(r.exec_time(), Duration::from_micros(200));
        assert_eq!(d.stats().busy, Duration::from_micros(200));
    }

    #[test]
    #[should_panic(expected = "bad MIG fraction")]
    fn mig_fraction_validated() {
        let _ = DeviceConfig::mig_instance(0.0);
    }

    #[test]
    fn utilization() {
        let mut d = dev();
        d.submit(launch(500, SimTime::ZERO), SimTime::ZERO, LaunchSource::Direct);
        let horizon = SimTime(1_000_000); // 1ms
        assert!((d.stats().utilization(horizon) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn timesliced_evict_unstarted_rolls_back_everything() {
        let mut d = dev();
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        let r2 = d.submit(launch(50, t0), t0, LaunchSource::GapFill);
        assert_eq!(d.free_at(), r2.finished_at);
        // r2 queued behind r1 and not yet started: full eviction.
        assert!(d.preempt(&r2, r2.started_at, Duration::ZERO));
        assert_eq!(d.free_at(), r1.finished_at, "tail rewound to the cut");
        assert_eq!(d.stats().kernels, 1);
        assert_eq!(d.stats().busy, Duration::from_micros(100));
        assert_eq!(d.stats().fill_kernels, 0);
        assert_eq!(d.stats().fill_busy, Duration::ZERO);
        assert_eq!(d.pending(SimTime(10_000)), 1);
        assert_eq!(d.pending_fills(SimTime(10_000)), 0);
        // The freed tail is immediately reusable, and the tombstone
        // drains without disturbing the counters.
        let r3 = d.submit(launch(10, SimTime(10_000)), SimTime(10_000), LaunchSource::Direct);
        assert_eq!(r3.started_at, r1.finished_at);
        assert_eq!(d.pending(SimTime(400_000)), 0);
        assert_eq!(d.pending_fills(SimTime(400_000)), 0);
    }

    #[test]
    fn timesliced_split_keeps_partial_and_charges_penalty() {
        let mut d = dev();
        let t0 = SimTime::ZERO;
        let r = d.submit(launch(100, t0), t0, LaunchSource::GapFill);
        // Runs 5–105 µs; cut mid-flight at 55 µs with a 10 µs penalty.
        let cut = SimTime(55_000);
        assert!(d.preempt(&r, cut, Duration::from_micros(10)));
        assert_eq!(d.free_at(), SimTime(65_000), "cut + penalty dead time");
        // The executed prefix stays busy; the launch still counts.
        assert_eq!(d.stats().kernels, 1);
        assert_eq!(d.stats().busy, Duration::from_micros(50));
        assert_eq!(d.stats().fill_busy, Duration::from_micros(50));
        // The partial execution is pending until the cut, then drains.
        assert_eq!(d.pending(SimTime(10_000)), 1);
        assert_eq!(d.pending_fills(SimTime(10_000)), 1);
        assert_eq!(d.pending(SimTime(60_000)), 0);
        assert_eq!(d.pending_fills(SimTime(60_000)), 0);
    }

    #[test]
    fn preempt_refuses_non_tail_and_bad_cuts() {
        let mut d = dev();
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        let r2 = d.submit(launch(50, t0), t0, LaunchSource::Direct);
        // r1 is not the FIFO tail: r2 has a committed start behind it.
        assert!(!d.preempt(&r1, r1.started_at, Duration::ZERO));
        // Cuts outside [started_at, finished_at) are meaningless.
        assert!(!d.preempt(&r2, SimTime(r2.started_at.nanos() - 1), Duration::ZERO));
        assert!(!d.preempt(&r2, r2.finished_at, Duration::ZERO));
        assert_eq!(d.stats().kernels, 2);
        assert_eq!(d.free_at(), r2.finished_at);
    }

    #[test]
    fn mig_preempt_rewinds_only_its_slice() {
        let mut d = SimDevice::new(DeviceConfig {
            backend: ConcurrencyBackend::mig(2),
            ..DeviceConfig::default()
        });
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct); // slice 0: 5–205
        let r2 = d.submit(launch(50, t0), t0, LaunchSource::GapFill); // slice 1: 5–105
        assert!(d.preempt(&r2, r2.started_at, Duration::ZERO));
        assert_eq!(d.free_at(), r1.finished_at, "slice 0 unaffected");
        // The freed slice takes the next launch at its readiness.
        let r3 = d.submit(launch(10, SimTime(10_000)), SimTime(10_000), LaunchSource::Direct);
        assert_eq!(r3.started_at, SimTime(15_000), "takes the freed slice");
        assert_eq!(d.stats().kernels, 2);
    }

    #[test]
    fn mps_preempt_refunds_busy_and_stops_dilating() {
        let mut d = SimDevice::new(DeviceConfig {
            backend: ConcurrencyBackend::MpsSpatial { dilation: 0.5 },
            ..DeviceConfig::default()
        });
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct); // 5–105
        let r2 = d.submit(launch(100, t0), t0, LaunchSource::GapFill); // dilated: 5–155
        assert_eq!(r2.exec_time(), Duration::from_micros(150));
        assert!(d.preempt(&r2, SimTime(55_000), Duration::ZERO));
        assert_eq!(d.stats().busy, Duration::from_micros(150), "refunded the tail");
        // The cancelled co-resident no longer dilates later arrivals:
        // at ready=65µs only r1 (finishes 105µs) is still running.
        let r3 = d.submit(launch(100, SimTime(60_000)), SimTime(60_000), LaunchSource::Direct);
        assert_eq!(r3.exec_time(), Duration::from_micros(150), "one co-resident");
        assert_eq!(r1.exec_time(), Duration::from_micros(100));
    }

    /// The backend seam's contract: `TimeSliced` must reproduce the
    /// pre-seam single-FIFO-queue arithmetic *byte for byte*. The
    /// reference below is that arithmetic inlined; a seeded launch
    /// stream (bursts, idle gaps, mixed durations) is pushed through
    /// both and every `(start, finish)` pair must match exactly.
    #[test]
    fn timesliced_matches_pre_seam_fifo_reference() {
        let latency = Duration::from_micros(5);
        for seed in [1u64, 0xBEEF, 0xF1C1_7000] {
            let mut d = SimDevice::new(DeviceConfig::default());
            let mut ref_free = SimTime::ZERO; // reference device state
            let mut state = seed;
            let mut now = SimTime::ZERO;
            for i in 0..500 {
                // splitmix64 — same generator the sim derives seeds with.
                state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
                let mut z = state;
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
                z ^= z >> 31;
                let dur_us = 10 + z % 300;
                let gap_ns = if z % 3 == 0 { 0 } else { (z >> 32) % 200_000 };
                now = now + Duration::from_nanos(gap_ns);
                let src = if i % 4 == 0 { LaunchSource::GapFill } else { LaunchSource::Direct };
                let rec = d.submit(launch(dur_us, now), now, src);
                // Pre-seam reference: start = max(ready, free); free = finish.
                let ref_start = (now + latency).max(ref_free);
                let ref_finish = ref_start + Duration::from_micros(dur_us);
                ref_free = ref_finish;
                assert_eq!(rec.started_at, ref_start, "seed {seed} kernel {i}");
                assert_eq!(rec.finished_at, ref_finish, "seed {seed} kernel {i}");
                assert_eq!(d.free_at(), ref_free);
            }
        }
    }

    #[test]
    fn mps_overlaps_and_dilates_by_occupancy() {
        let mut d = SimDevice::new(DeviceConfig {
            backend: ConcurrencyBackend::MpsSpatial { dilation: 0.5 },
            ..DeviceConfig::default()
        });
        let t0 = SimTime::ZERO;
        // First kernel: nothing co-resident → base duration.
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        assert_eq!(r1.started_at, SimTime(5_000));
        assert_eq!(r1.exec_time(), Duration::from_micros(100));
        // Second kernel while the first runs: starts immediately (no
        // FIFO wait) but runs 1.5× slower.
        let r2 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        assert_eq!(r2.started_at, SimTime(5_000), "no queueing behind r1");
        assert_eq!(r2.exec_time(), Duration::from_micros(150));
        // Third kernel after both drained: back to base duration.
        let t3 = SimTime(1_000_000);
        let r3 = d.submit(launch(100, t3), t3, LaunchSource::Direct);
        assert_eq!(r3.exec_time(), Duration::from_micros(100));
    }

    #[test]
    fn mps_zero_dilation_is_perfect_overlap() {
        let mut d = SimDevice::new(DeviceConfig {
            backend: ConcurrencyBackend::MpsSpatial { dilation: 0.0 },
            ..DeviceConfig::default()
        });
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        let r2 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        assert_eq!(r1.finished_at, r2.finished_at);
    }

    #[test]
    fn mig_partition_parallel_slices_each_slower() {
        // Two hard slices: two kernels run in parallel, each at half
        // throughput; a third queues behind the earlier-free slice.
        let mut d = SimDevice::new(DeviceConfig {
            backend: ConcurrencyBackend::mig(2),
            ..DeviceConfig::default()
        });
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        let r2 = d.submit(launch(50, t0), t0, LaunchSource::Direct);
        assert_eq!(r1.started_at, SimTime(5_000));
        assert_eq!(r2.started_at, SimTime(5_000), "second slice is free");
        assert_eq!(r1.exec_time(), Duration::from_micros(200), "half throughput");
        assert_eq!(r2.exec_time(), Duration::from_micros(100));
        // Third kernel queues on slice 1 (frees at 105us < 205us).
        let r3 = d.submit(launch(10, t0), t0, LaunchSource::Direct);
        assert_eq!(r3.started_at, SimTime(105_000));
        assert_eq!(r3.exec_time(), Duration::from_micros(20));
    }

    #[test]
    fn mig_single_slice_degenerates_to_fifo() {
        let mut d = SimDevice::new(DeviceConfig {
            backend: ConcurrencyBackend::mig(1),
            ..DeviceConfig::default()
        });
        let t0 = SimTime::ZERO;
        let r1 = d.submit(launch(100, t0), t0, LaunchSource::Direct);
        let r2 = d.submit(launch(50, t0), t0, LaunchSource::Direct);
        assert_eq!(r1.finished_at, SimTime(105_000));
        assert_eq!(r2.started_at, SimTime(105_000), "serialized like FIFO");
    }

    #[test]
    #[should_panic(expected = "bad MIG slice count")]
    fn mig_slice_count_validated() {
        let _ = ConcurrencyBackend::mig(0);
    }
}
