//! Discrete-event GPU device simulation.
//!
//! This is the substrate the paper ran on real silicon: a single GPU with
//! a **FIFO device queue** (the property both NVIDIA default sharing and
//! FIKIT build on), plus the CPU-side launch loop of each hosted service.
//!
//! The model (DESIGN.md §6):
//!
//! * How the device runs co-resident kernels is a pluggable
//!   [`ConcurrencyBackend`] (ADR-006). The default, `TimeSliced`,
//!   executes exactly one kernel at a time in submission (FIFO) order,
//!   non-preemptively — kernel-granularity scheduling is the paper's
//!   whole premise, and this backend reproduces the pre-seam simulator
//!   byte for byte. `MpsSpatial` overlaps co-resident kernels with
//!   occupancy-dilated execution; `MigPartition` runs hard slices, each
//!   its own little FIFO device. Every backend stays non-preemptive, so
//!   determinism is unchanged.
//! * Each service is a *closed-loop* CPU process: it issues kernel *i+1*
//!   of a task only after observing kernel *i* complete and then spending
//!   the trace's CPU-side gap (post-processing, glue code, launch
//!   overhead). In exclusive mode this reproduces Fig 1's inter-kernel
//!   device idle exactly; in shared modes the queueing delays compound
//!   through the loop — which is precisely the JCT inflation the paper
//!   measures.
//! * Submitting a kernel is deterministic: a FIFO, non-preemptive device
//!   means `(start, finish)` are fixed at submission time, so the device
//!   returns the completed [`KernelRecord`] synchronously; the driver
//!   parks it in the per-sim [`KernelArena`] and schedules a completion
//!   *event* (carrying only the [`RecordSlot`] handle) at `finished_at`.
//!
//! The event core is a calendar-queue [`CalendarWheel`] (ADR-003): O(1)
//! amortized push/pop for the dense near-future band, with far-future
//! events on a heap **overflow ring** — see DESIGN.md §Perf.

mod arena;
mod backend;
mod device;
mod event;
mod process;
mod wheel;

pub use arena::{KernelArena, RecordSlot};
pub use backend::{ConcurrencyBackend, DEFAULT_MIG_SLICES, DEFAULT_MPS_DILATION};
pub use device::{DeviceConfig, DeviceStats, SimDevice};
pub use event::{Event, EventQueue};
pub use process::{ProcessAction, ServiceProcess, Stage, TaskOutcome};
pub use wheel::{BaselineHeapQueue, CalendarWheel, DEFAULT_BUCKETS, DEFAULT_SHIFT};
