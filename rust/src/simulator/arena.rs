//! Slab arena for in-flight [`KernelRecord`]s (ADR-003).
//!
//! A `KernelDone` event used to carry its full `KernelRecord` payload
//! inline, making `Event` a large move-heavy enum. The arena parks the
//! record between submission and completion and the event carries only a
//! [`RecordSlot`] — a `u32` index — so `Event` is small and `Copy` and
//! the event core moves fixed-width entries only.
//!
//! Freed slots go on a free list and are reused LIFO; after warm-up the
//! steady-state insert/take cycle performs zero heap allocations (gated
//! by `tests/hotpath_alloc.rs`). No unsafe: slots are `Option`s and a
//! double-take panics instead of aliasing.

use crate::core::KernelRecord;

/// Handle to a parked [`KernelRecord`] — the `KernelDone` payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RecordSlot(u32);

/// Slab + free list of in-flight kernel records, one per [`GpuSim`].
///
/// [`GpuSim`]: crate::coordinator::driver::GpuSim
#[derive(Debug, Default)]
pub struct KernelArena {
    slots: Vec<Option<KernelRecord>>,
    free: Vec<u32>,
}

impl KernelArena {
    pub fn new() -> KernelArena {
        KernelArena::default()
    }

    /// Records currently parked.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Park `record`, returning its slot. Reuses a freed slot when one
    /// exists; grows the slab otherwise.
    pub fn insert(&mut self, record: KernelRecord) -> RecordSlot {
        match self.free.pop() {
            Some(idx) => {
                debug_assert!(self.slots[idx as usize].is_none(), "free-list slot occupied");
                self.slots[idx as usize] = Some(record);
                RecordSlot(idx)
            }
            None => {
                let idx = u32::try_from(self.slots.len()).expect("arena exceeds u32 slots");
                self.slots.push(Some(record));
                RecordSlot(idx)
            }
        }
    }

    /// Remove and return the record parked at `slot`.
    ///
    /// Panics on a stale or double-taken slot — that would mean a
    /// completion event fired twice, which the simulator must never do.
    pub fn take(&mut self, slot: RecordSlot) -> KernelRecord {
        let record = self.slots[slot.0 as usize]
            .take()
            .expect("take of an empty arena slot");
        self.free.push(slot.0);
        record
    }

    /// Drop every parked record but keep the slab and free-list storage
    /// (the multi-run reuse path, paired with `EventQueue::clear`).
    pub fn clear(&mut self) {
        self.free.clear();
        // Rebuild the free list in descending order so a cleared arena
        // hands out slot 0 first — byte-identical replay across reuse.
        for idx in (0..self.slots.len() as u32).rev() {
            self.slots[idx as usize] = None;
            self.free.push(idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{
        Dim3, KernelHandle, KernelId, LaunchSource, Priority, SimTime, TaskHandle, TaskId, TaskKey,
    };

    fn record(seq: u32) -> KernelRecord {
        KernelRecord {
            task_key: TaskKey::new("svc"),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(seq as u64),
            kernel: KernelId::new("k", Dim3::x(1), Dim3::x(32)),
            kernel_handle: KernelHandle::UNBOUND,
            priority: Priority::P0,
            seq,
            source: LaunchSource::Direct,
            issued_at: SimTime::ZERO,
            started_at: SimTime::ZERO,
            finished_at: SimTime(10_000),
        }
    }

    #[test]
    fn insert_take_roundtrip_reuses_slots() {
        let mut arena = KernelArena::new();
        let a = arena.insert(record(1));
        let b = arena.insert(record(2));
        assert_ne!(a, b);
        assert_eq!(arena.len(), 2);
        assert_eq!(arena.take(a).seq, 1);
        assert_eq!(arena.len(), 1);
        // Freed slot is reused before the slab grows.
        let c = arena.insert(record(3));
        assert_eq!(c, a);
        assert_eq!(arena.take(b).seq, 2);
        assert_eq!(arena.take(c).seq, 3);
        assert!(arena.is_empty());
    }

    #[test]
    #[should_panic(expected = "empty arena slot")]
    fn double_take_panics() {
        let mut arena = KernelArena::new();
        let slot = arena.insert(record(1));
        let _ = arena.take(slot);
        let _ = arena.take(slot);
    }

    #[test]
    fn clear_retains_capacity_and_restarts_slot_order() {
        let mut arena = KernelArena::new();
        let first = arena.insert(record(1));
        arena.insert(record(2));
        arena.insert(record(3));
        arena.clear();
        assert!(arena.is_empty());
        // After clear, allocation order restarts at slot 0.
        let again = arena.insert(record(4));
        assert_eq!(again, first);
    }
}
