//! Slab arena for in-flight [`KernelRecord`]s (ADR-003).
//!
//! A `KernelDone` event used to carry its full `KernelRecord` payload
//! inline, making `Event` a large move-heavy enum. The arena parks the
//! record between submission and completion and the event carries only a
//! [`RecordSlot`] — a `u32` index — so `Event` is small and `Copy` and
//! the event core moves fixed-width entries only.
//!
//! Freed slots go on a free list and are reused LIFO; after warm-up the
//! steady-state insert/take cycle performs zero heap allocations (gated
//! by `tests/hotpath_alloc.rs`). No unsafe: slots are `Option`s and a
//! double-take panics instead of aliasing.

use crate::core::KernelRecord;

/// Handle to a parked [`KernelRecord`] — the `KernelDone` payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RecordSlot(u32);

/// Slab + free list of in-flight kernel records, one per [`GpuSim`].
///
/// [`GpuSim`]: crate::coordinator::driver::GpuSim
#[derive(Debug, Default)]
pub struct KernelArena {
    slots: Vec<Option<KernelRecord>>,
    free: Vec<u32>,
    /// Tombstones for preempted records: the slot is emptied by
    /// [`KernelArena::cancel`] but stays *reserved* (off the free list)
    /// until its stale `KernelDone` event pops and calls
    /// [`KernelArena::take_if_live`]. Reserving preserves the LIFO
    /// slot-reuse order, keeping replays byte-identical whether or not a
    /// cancellation happened earlier in the run.
    cancelled: Vec<bool>,
}

impl KernelArena {
    pub fn new() -> KernelArena {
        KernelArena::default()
    }

    /// Records currently parked.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Park `record`, returning its slot. Reuses a freed slot when one
    /// exists; grows the slab otherwise.
    pub fn insert(&mut self, record: KernelRecord) -> RecordSlot {
        match self.free.pop() {
            Some(idx) => {
                debug_assert!(self.slots[idx as usize].is_none(), "free-list slot occupied");
                self.slots[idx as usize] = Some(record);
                RecordSlot(idx)
            }
            None => {
                let idx = u32::try_from(self.slots.len()).expect("arena exceeds u32 slots");
                self.slots.push(Some(record));
                self.cancelled.push(false);
                RecordSlot(idx)
            }
        }
    }

    /// Peek at the record parked at `slot` (None if taken or cancelled).
    pub fn get(&self, slot: RecordSlot) -> Option<&KernelRecord> {
        self.slots[slot.0 as usize].as_ref()
    }

    /// Preempt the record at `slot`: remove and return it, leaving a
    /// tombstone so the slot stays reserved until the in-flight
    /// `KernelDone` event for it pops and is discarded by
    /// [`KernelArena::take_if_live`].
    ///
    /// Panics if the slot is already empty (double cancel / cancel after
    /// take), which would mean the driver lost track of an in-flight set.
    pub fn cancel(&mut self, slot: RecordSlot) -> KernelRecord {
        let record = self.slots[slot.0 as usize]
            .take()
            .expect("cancel of an empty arena slot");
        debug_assert!(!self.cancelled[slot.0 as usize], "double cancel");
        self.cancelled[slot.0 as usize] = true;
        record
    }

    /// Completion-side take that tolerates cancellation: returns the
    /// record if the slot is live, or `None` (freeing the slot) if it was
    /// cancelled by a preemption. Panics on a plain-empty slot exactly
    /// like [`KernelArena::take`] — only a cancellation may absorb an
    /// event.
    pub fn take_if_live(&mut self, slot: RecordSlot) -> Option<KernelRecord> {
        if self.cancelled[slot.0 as usize] {
            debug_assert!(self.slots[slot.0 as usize].is_none());
            self.cancelled[slot.0 as usize] = false;
            self.free.push(slot.0);
            return None;
        }
        Some(self.take(slot))
    }

    /// Remove and return the record parked at `slot`.
    ///
    /// Panics on a stale or double-taken slot — that would mean a
    /// completion event fired twice, which the simulator must never do.
    pub fn take(&mut self, slot: RecordSlot) -> KernelRecord {
        let record = self.slots[slot.0 as usize]
            .take()
            .expect("take of an empty arena slot");
        self.free.push(slot.0);
        record
    }

    /// Drop every parked record but keep the slab and free-list storage
    /// (the multi-run reuse path, paired with `EventQueue::clear`).
    pub fn clear(&mut self) {
        self.free.clear();
        // Rebuild the free list in descending order so a cleared arena
        // hands out slot 0 first — byte-identical replay across reuse.
        for idx in (0..self.slots.len() as u32).rev() {
            self.slots[idx as usize] = None;
            self.cancelled[idx as usize] = false;
            self.free.push(idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{
        Dim3, KernelHandle, KernelId, LaunchSource, Priority, SimTime, TaskHandle, TaskId, TaskKey,
    };

    fn record(seq: u32) -> KernelRecord {
        KernelRecord {
            task_key: TaskKey::new("svc"),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(seq as u64),
            kernel: KernelId::new("k", Dim3::x(1), Dim3::x(32)),
            kernel_handle: KernelHandle::UNBOUND,
            priority: Priority::P0,
            seq,
            source: LaunchSource::Direct,
            issued_at: SimTime::ZERO,
            started_at: SimTime::ZERO,
            finished_at: SimTime(10_000),
        }
    }

    #[test]
    fn insert_take_roundtrip_reuses_slots() {
        let mut arena = KernelArena::new();
        let a = arena.insert(record(1));
        let b = arena.insert(record(2));
        assert_ne!(a, b);
        assert_eq!(arena.len(), 2);
        assert_eq!(arena.take(a).seq, 1);
        assert_eq!(arena.len(), 1);
        // Freed slot is reused before the slab grows.
        let c = arena.insert(record(3));
        assert_eq!(c, a);
        assert_eq!(arena.take(b).seq, 2);
        assert_eq!(arena.take(c).seq, 3);
        assert!(arena.is_empty());
    }

    #[test]
    #[should_panic(expected = "empty arena slot")]
    fn double_take_panics() {
        let mut arena = KernelArena::new();
        let slot = arena.insert(record(1));
        let _ = arena.take(slot);
        let _ = arena.take(slot);
    }

    #[test]
    fn cancel_reserves_slot_until_stale_event_pops() {
        let mut arena = KernelArena::new();
        let a = arena.insert(record(1));
        let cancelled = arena.cancel(a);
        assert_eq!(cancelled.seq, 1);
        assert!(arena.get(a).is_none());
        // The slot is tombstoned, not freed: a fresh insert must NOT
        // reuse it while its stale completion event is still in flight.
        let b = arena.insert(record(2));
        assert_ne!(a, b);
        // The stale event pops: take_if_live absorbs it and frees the slot.
        assert!(arena.take_if_live(a).is_none());
        let c = arena.insert(record(3));
        assert_eq!(c, a);
        // Live slots still take normally through take_if_live.
        assert_eq!(arena.take_if_live(b).unwrap().seq, 2);
        assert_eq!(arena.take_if_live(c).unwrap().seq, 3);
    }

    #[test]
    #[should_panic(expected = "cancel of an empty arena slot")]
    fn cancel_after_take_panics() {
        let mut arena = KernelArena::new();
        let slot = arena.insert(record(1));
        let _ = arena.take(slot);
        let _ = arena.cancel(slot);
    }

    #[test]
    fn clear_retains_capacity_and_restarts_slot_order() {
        let mut arena = KernelArena::new();
        let first = arena.insert(record(1));
        arena.insert(record(2));
        arena.insert(record(3));
        arena.clear();
        assert!(arena.is_empty());
        // After clear, allocation order restarts at slot 0.
        let again = arena.insert(record(4));
        assert_eq!(again, first);
    }
}
