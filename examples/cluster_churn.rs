//! Dynamic cluster serving with churn: services arrive, live, migrate,
//! and depart while the fleet stays up (DESIGN.md §8).
//!
//! Two acts:
//!
//! 1. **The rescue.** A workload-blind LeastLoaded placer is forced to
//!    park a dense low-priority stream next to the high-priority
//!    detector (the compatible device is momentarily full). We run the
//!    exact same schedule twice — QoS migration off, then on — and show
//!    the violation count and the windowed slowdown trajectory recover.
//! 2. **Steady churn.** Seeded Poisson arrivals over a 3-GPU fleet with
//!    per-GPU FIKIT coordinators and compatibility-aware BestMatch
//!    placement: the serving regime the ROADMAP points at.
//!
//! ```bash
//! cargo run --release --example cluster_churn
//! ```

use fikit::cluster::{run_churn, ChurnConfig, CompatMatrix, PlacementPolicy};
use fikit::coordinator::Mode;
use fikit::core::{Duration, Priority, SimTime};
use fikit::workload::{ArrivalProcess, MixEntry, ModelKind, ServiceArrival};

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

/// Act 1: the scripted rescue schedule (see the cluster_churn experiment
/// for the same scenario under shape checks).
fn rescue(migration: bool) -> ChurnConfig {
    let arrivals = ArrivalProcess::Trace(vec![
        ServiceArrival::new(
            SimTime::ZERO,
            ModelKind::KeypointRcnnResnet50Fpn,
            Priority::P0,
            ms(3_000),
        ),
        ServiceArrival::new(SimTime(10_000_000), ModelKind::Vgg16, Priority::P7, ms(400)),
        ServiceArrival::new(SimTime(20_000_000), ModelKind::Vgg16, Priority::P7, ms(3_000)),
        ServiceArrival::new(
            SimTime(30_000_000),
            ModelKind::Resnet101,
            Priority::P6,
            ms(3_000),
        ),
    ]);
    let mut cfg = ChurnConfig::new(2, PlacementPolicy::LeastLoaded, arrivals);
    cfg.capacity = 2;
    cfg.mode = Mode::Sharing;
    cfg.qos.high_slowdown_bound = 1.3;
    cfg.qos.scan_interval = ms(250);
    cfg.qos.window = ms(1_000);
    cfg.qos.migration = migration;
    cfg.metrics_window = ms(500);
    cfg
}

/// Act 2: Poisson churn on a FIKIT fleet.
fn steady_churn() -> ChurnConfig {
    let mix = vec![
        MixEntry::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 1.0),
        MixEntry::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P1, 1.0),
        MixEntry::new(ModelKind::FcnResnet50, Priority::P5, 2.0),
        MixEntry::new(ModelKind::Resnet101, Priority::P6, 2.0),
        MixEntry::new(ModelKind::Vgg16, Priority::P7, 1.0),
    ];
    let arrivals = ArrivalProcess::Poisson {
        mean_interarrival: ms(300),
        mean_lifetime: ms(600),
        mix,
        horizon: ms(2_000),
    };
    let mut cfg = ChurnConfig::new(3, PlacementPolicy::BestMatch, arrivals);
    cfg.capacity = 2;
    cfg.mode = Mode::Fikit;
    cfg.qos.scan_interval = ms(250);
    cfg.qos.window = ms(750);
    cfg.metrics_window = ms(500);
    cfg
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let compat = CompatMatrix::new(); // analytic predictions; swap in a
                                      // measured matrix via CompatMatrix::load

    println!("== Act 1: the rescue (same schedule, migration off vs on) ==\n");
    for migration in [false, true] {
        let report = run_churn(&rescue(migration), &compat)?;
        println!(
            "migration {}:",
            if migration { "ON " } else { "OFF" }
        );
        println!("{}", report.summary());
    }
    println!(
        "With migration ON, the scanner moves resnet101 off the detector's device\n\
         as soon as the short-lived vgg departs; the windowed high-priority slowdown\n\
         drops back under the bound instead of staying pinned above it.\n"
    );

    println!("== Act 2: steady Poisson churn on a FIKIT fleet ==\n");
    let report = run_churn(&steady_churn(), &compat)?;
    println!("{}", report.summary());
    println!(
        "Per-GPU FIKIT coordinators protect the high-priority tenants through\n\
         arrivals and departures; BestMatch placement keeps dense fillers away\n\
         from gappy detectors when it has the choice."
    );
    Ok(())
}
