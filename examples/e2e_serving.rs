//! **End-to-end driver** (DESIGN.md §1, the three-layer stack composed):
//! serve real compiled models through the FIKIT coordinator and report
//! latency/throughput.
//!
//! All three layers compose here:
//!
//! * **L1** — the Pallas kernels (tiled matmul, fused linear, softmax,
//!   layernorm) inside the artifacts,
//! * **L2** — the JAX models (`transformer_block`, `mlp_classifier`)
//!   AOT-lowered to `artifacts/*.hlo.txt`,
//! * **L3** — the Rust real-time engine executing them via PJRT under
//!   FIKIT scheduling (priority queues + BestPrioFit + fill windows +
//!   feedback), with a high-priority transformer service and a
//!   low-priority MLP batch service sharing the single CPU "device".
//!
//! Requires `make artifacts` first. The simulation-side counterpart of
//! this composition is mapped in DESIGN.md §5.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use fikit::coordinator::Mode;
use fikit::core::{Priority, TaskKey};
use fikit::metrics::TextTable;
use fikit::runtime::engine::{EngineConfig, RealTimeEngine, RtKernelStep, RtService};
use fikit::runtime::manifest::Manifest;
use std::time::Duration as StdDuration;

const HIGH: &str = "llm-serving-rt";

fn services(requests: u32) -> Vec<RtService> {
    let ms = StdDuration::from_millis;
    let mut svcs = vec![
        // High priority: a transformer-block inference pipeline with
        // CPU-side think gaps (tokenize/detokenize, sampling logic).
        RtService {
            key: TaskKey::new(HIGH),
            priority: Priority::P0,
            steps: vec![
                RtKernelStep { artifact: "layernorm_128x512".into(), think_gap: ms(12) },
                RtKernelStep { artifact: "transformer_block".into(), think_gap: ms(12) },
                RtKernelStep { artifact: "transformer_block".into(), think_gap: ms(8) },
                RtKernelStep { artifact: "softmax_128x512".into(), think_gap: ms(0) },
            ],
            requests,
            inter_request: ms(10),
        },
    ];
    // Three batch-scoring workers (a real batch tenant runs several),
    // no think time — pure background grind at priorities P4..P6.
    for (i, prio) in [Priority::P4, Priority::P5, Priority::P6].iter().enumerate() {
        svcs.push(RtService {
            key: TaskKey::new(format!("mlp-batch-{i}")),
            priority: *prio,
            steps: vec![
                RtKernelStep { artifact: "mlp_classifier".into(), think_gap: ms(0) },
                RtKernelStep { artifact: "matmul_128x512x512".into(), think_gap: ms(0) },
                RtKernelStep { artifact: "matmul_256x256x256".into(), think_gap: ms(0) },
            ],
            requests: requests * 2,
            inter_request: ms(0),
        });
    }
    svcs
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let requests: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let manifest = Manifest::load("artifacts")?;
    println!(
        "loaded manifest: {} artifacts (L1 Pallas kernels + L2 JAX models, AOT via PJRT)",
        manifest.artifacts.len()
    );

    let mut table = TextTable::new(&[
        "mode", "svc", "prio", "reqs", "mean JCT (ms)", "p95 (ms)", "CV",
    ]);
    let mut hp = Vec::new();

    for mode in [Mode::Sharing, Mode::Fikit] {
        let mut cfg = EngineConfig {
            mode,
            ..EngineConfig::default()
        };
        // Real compute drifts with machine load: let the sharing-stage
        // refiner track it (DESIGN.md §9).
        cfg.online.enabled = true;
        let engine = RealTimeEngine::new(cfg, services(requests), &manifest)?;
        // Measurement stage (real executions, real timings).
        let profiles = engine.profile()?;
        // Sharing stage.
        let report = engine.serve(&profiles)?;
        for svc in &report.services {
            table.row(vec![
                mode.to_string(),
                svc.key.to_string(),
                svc.priority.to_string(),
                svc.completed.to_string(),
                format!("{:.2}", svc.jct.mean_ms()),
                format!("{:.2}", svc.jct.p95.as_millis_f64()),
                format!("{:.3}", svc.jct.cv),
            ]);
        }
        let h = report.service(&TaskKey::new(HIGH)).unwrap().jct.mean_ms();
        hp.push(h);
        println!(
            "{mode}: executed {} real kernels in {:.2}s  (fills={} windows={} early_stops={} refined={})",
            report.kernels_executed,
            report.wall.as_secs_f64(),
            report.fills,
            report.windows,
            report.early_stops,
            report.profiles_refined,
        );
    }

    println!("\n{}", table.render());
    let speedup = hp[0] / hp[1];
    println!(
        "high-priority mean JCT: {:.2}ms (sharing) -> {:.2}ms (FIKIT) = {speedup:.2}x speedup\n\
         (real PJRT compute; the simulated counterpart is DESIGN.md §5)",
        hp[0], hp[1]
    );
    Ok(())
}
