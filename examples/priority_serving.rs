//! Priority serving: the paper's preemption scenario (§4.5.3) as an
//! application.
//!
//! A latency-critical recommender (high priority) fires a request every
//! 100 ms while a batch analytics service (low priority) grinds
//! continuously in the background. We compare all three modes the paper
//! evaluates — exclusive, default sharing, FIKIT — on the recommender's
//! tail latency and the analytics throughput.
//!
//! ```bash
//! cargo run --release --example priority_serving
//! ```

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::{run_experiment, ExperimentReport};
use fikit::coordinator::Mode;
use fikit::core::{Priority, TaskKey};
use fikit::metrics::TextTable;
use fikit::workload::ModelKind;

const RECO: &str = "recommender-rt";
const BATCH: &str = "analytics-batch";

fn build(mode: Mode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        mode,
        ..ExperimentConfig::default()
    };
    // 80 real-time requests, one every 100 ms.
    cfg.services.push(
        ServiceConfig::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P0)
            .every_ms(100, 80)
            .with_key(RECO),
    );
    // Background batch segmentation running the whole 8.5 s window.
    cfg.services.push(
        ServiceConfig::new(ModelKind::FcnResnet50, Priority::P6)
            .continuous_ms(8_500)
            .with_key(BATCH),
    );
    cfg
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut table = TextTable::new(&[
        "mode",
        "RT mean (ms)",
        "RT p95 (ms)",
        "RT p99 (ms)",
        "batch tasks done",
        "batch mean (ms)",
        "device util",
    ]);

    for mode in [Mode::Exclusive, Mode::Sharing, Mode::Fikit] {
        let report: ExperimentReport = run_experiment(&build(mode))?;
        let rt = report.service(&TaskKey::new(RECO)).unwrap();
        let batch = report.service(&TaskKey::new(BATCH)).unwrap();
        table.row(vec![
            mode.to_string(),
            format!("{:.2}", rt.jct.mean_ms()),
            format!("{:.2}", rt.jct.p95.as_millis_f64()),
            format!("{:.2}", rt.jct.p99.as_millis_f64()),
            batch.completed.to_string(),
            format!("{:.2}", batch.jct.mean_ms()),
            format!("{:.2}", report.device.utilization(report.sim_end)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "FIKIT should give the real-time service near-exclusive latency while the\n\
         batch service scavenges its inter-kernel gaps (compare device utilization)."
    );
    Ok(())
}
