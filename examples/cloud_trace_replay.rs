//! Cloud trace replay: a multi-tenant GPU with four services at mixed
//! priorities, driven from a JSON experiment config — the "containerized
//! cloud computing environment" of the paper's introduction.
//!
//! Demonstrates: config round-trip (write → load → run), the profile
//! store lifecycle (measure once, persist, reuse), and per-tenant
//! QoS reporting across priority levels.
//!
//! ```bash
//! cargo run --release --example cloud_trace_replay
//! ```

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::{profile_service, run_with_profiles};
use fikit::coordinator::Mode;
use fikit::core::Priority;
use fikit::profile::ProfileStore;
use fikit::workload::ModelKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- author a config and persist it (what an operator would do) ---
    let mut cfg = ExperimentConfig {
        mode: Mode::Fikit,
        seed: 2026,
        ..ExperimentConfig::default()
    };
    cfg.services.push(
        ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
            .every_ms(120, 40)
            .with_key("tenant-a/pose-rt"),
    );
    cfg.services.push(
        ServiceConfig::new(ModelKind::Resnet50, Priority::P2)
            .every_ms(60, 80)
            .with_key("tenant-b/classify-std"),
    );
    cfg.services.push(
        ServiceConfig::new(ModelKind::Deeplabv3Resnet101, Priority::P5)
            .continuous_ms(5_000)
            .with_key("tenant-c/segment-batch"),
    );
    cfg.services.push(
        ServiceConfig::new(ModelKind::Vgg16, Priority::P8)
            .continuous_ms(5_000)
            .with_key("tenant-d/embed-scavenger"),
    );

    let dir = std::env::temp_dir().join("fikit-cloud-replay");
    std::fs::create_dir_all(&dir)?;
    let cfg_path = dir.join("experiment.json");
    std::fs::write(&cfg_path, cfg.to_json().encode_pretty())?;
    let cfg = ExperimentConfig::from_json_file(&cfg_path)?;
    println!("loaded experiment config from {}", cfg_path.display());

    // --- measurement stage: profile each service once, persist ---
    let store_path = dir.join("profiles.json");
    let profiles = if store_path.exists() {
        println!("reusing persisted profiles from {}", store_path.display());
        ProfileStore::load(&store_path)?
    } else {
        let mut store = ProfileStore::new();
        for svc in &cfg.services {
            let r = profile_service(&cfg, svc)?;
            println!(
                "  measured {:<28} {} unique kernel ids over {} runs",
                r.profile.task_key.to_string(),
                r.profile.num_unique(),
                r.profile.runs
            );
            store.insert(r.profile);
        }
        store.save(&store_path)?;
        println!("persisted profiles -> {}", store_path.display());
        store
    };

    // --- sharing stage: serve all four tenants ---
    let report = run_with_profiles(&cfg, &profiles)?;
    println!("\n{}", report.summary());

    // QoS ordering check: higher priority ⇒ better relative latency.
    let mut rows: Vec<(Priority, f64)> = report
        .services
        .iter()
        .map(|s| {
            let solo = s.model.spec().mean_jct().as_millis_f64();
            (s.priority, s.jct.mean_ms() / solo)
        })
        .collect();
    rows.sort_by_key(|(p, _)| *p);
    println!("per-tenant slowdown vs solo (priority order):");
    for (p, slowdown) in rows {
        println!("  {p}: {slowdown:.2}x");
    }
    Ok(())
}
