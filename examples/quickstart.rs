//! Quickstart: two inference services sharing one GPU under FIKIT.
//!
//! A high-priority detector (keypointrcnn) and a low-priority segmenter
//! (fcn_resnet50) issue 100 inferences each, concurrently. We run the
//! same workload under NVIDIA default sharing and under FIKIT and
//! compare the high-priority JCT.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::run_experiment;
use fikit::coordinator::Mode;
use fikit::core::Priority;
use fikit::metrics::speedup;
use fikit::workload::ModelKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let build = |mode: Mode| {
        let mut cfg = ExperimentConfig {
            mode,
            ..ExperimentConfig::default()
        };
        cfg.services.push(
            ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
                .tasks(100)
                .with_key("detector-high"),
        );
        cfg.services.push(
            ServiceConfig::new(ModelKind::FcnResnet50, Priority::P3)
                .tasks(100)
                .with_key("segmenter-low"),
        );
        cfg
    };

    println!("--- NVIDIA default sharing ---");
    let share = run_experiment(&build(Mode::Sharing))?;
    println!("{}", share.summary());

    println!("--- FIKIT (profile + priority + gap filling) ---");
    let fikit = run_experiment(&build(Mode::Fikit))?;
    println!("{}", fikit.summary());

    let hp_share = &share.by_priority(Priority::P0).unwrap().jct;
    let hp_fikit = &fikit.by_priority(Priority::P0).unwrap().jct;
    let lp_share = &share.by_priority(Priority::P3).unwrap().jct;
    let lp_fikit = &fikit.by_priority(Priority::P3).unwrap().jct;

    println!(
        "high-priority JCT: {:.2}ms (sharing) -> {:.2}ms (FIKIT)  = {:.2}x speedup",
        hp_share.mean_ms(),
        hp_fikit.mean_ms(),
        speedup(hp_share, hp_fikit),
    );
    println!(
        "low-priority  JCT: {:.2}ms (sharing) -> {:.2}ms (FIKIT)  = {:.2}x (the price of priority)",
        lp_share.mean_ms(),
        lp_fikit.mean_ms(),
        speedup(lp_share, lp_fikit),
    );
    let sched = fikit.scheduler.as_ref().unwrap();
    println!(
        "FIKIT filled {} low-priority kernels into {} gap windows ({} early stops by feedback)",
        sched.fills, sched.feedback.windows, sched.feedback.early_stops
    );
    Ok(())
}
