"""L2 — the JAX inference models composed of the L1 Pallas kernels.

These are the "hosted ML services" of the end-to-end example: the Rust
coordinator serves them as real compute through PJRT. Two models:

* :class:`MlpClassifier` — a small MLP image classifier (the AlexNet-class
  dense service of the paper's zoo).
* :class:`TransformerBlock` — one pre-norm transformer block with
  single-head self-attention (the heavier, modern serving workload).

Every dense op routes through the Pallas kernels so the whole graph
lowers into one HLO module containing the L1 compute.
"""

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import fused_linear, layernorm, matmul, softmax


@dataclasses.dataclass(frozen=True)
class MlpClassifier:
    """3-layer MLP classifier: fused_linear ×3 → softmax head."""

    batch: int = 32
    d_in: int = 256
    d_hidden: int = 512
    n_classes: int = 64

    def init(self, seed: int = 0):
        """He-initialized parameters as a flat tuple (AOT-friendly)."""
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 3)
        he = lambda key, i, o: jax.random.normal(key, (i, o), jnp.float32) * (2.0 / i) ** 0.5
        return (
            he(ks[0], self.d_in, self.d_hidden),
            jnp.zeros((self.d_hidden,), jnp.float32),
            he(ks[1], self.d_hidden, self.d_hidden),
            jnp.zeros((self.d_hidden,), jnp.float32),
            he(ks[2], self.d_hidden, self.n_classes),
            jnp.zeros((self.n_classes,), jnp.float32),
        )

    def apply(self, x, w1, b1, w2, b2, w3, b3):
        """Forward pass: class probabilities ``(batch, n_classes)``."""
        h = fused_linear(x, w1, b1, activation="relu")
        h = fused_linear(h, w2, b2, activation="gelu")
        logits = fused_linear(h, w3, b3, activation="none")
        return softmax(logits)

    def input_shapes(self):
        p = [
            (self.batch, self.d_in),
            (self.d_in, self.d_hidden),
            (self.d_hidden,),
            (self.d_hidden, self.d_hidden),
            (self.d_hidden,),
            (self.d_hidden, self.n_classes),
            (self.n_classes,),
        ]
        return [jax.ShapeDtypeStruct(s, jnp.float32) for s in p]


@dataclasses.dataclass(frozen=True)
class TransformerBlock:
    """Pre-norm transformer block, single-head attention + MLP.

    y  = x + Wo · softmax(QKᵀ/√d) · V,   Q/K/V = LN(x) · Wq/Wk/Wv
    out = y + W2 · gelu(W1 · LN(y) + b1) + b2
    """

    seq: int = 64
    d_model: int = 256
    d_ff: int = 512

    def init(self, seed: int = 0):
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 6)
        d, f = self.d_model, self.d_ff
        w = lambda key, i, o: jax.random.normal(key, (i, o), jnp.float32) * (1.0 / i) ** 0.5
        return (
            w(ks[0], d, d),  # wq
            w(ks[1], d, d),  # wk
            w(ks[2], d, d),  # wv
            w(ks[3], d, d),  # wo
            w(ks[4], d, f),  # w1
            jnp.zeros((f,), jnp.float32),  # b1
            w(ks[5], f, d),  # w2
            jnp.zeros((d,), jnp.float32),  # b2
            jnp.ones((d,), jnp.float32),  # gamma1
            jnp.zeros((d,), jnp.float32),  # beta1
            jnp.ones((d,), jnp.float32),  # gamma2
            jnp.zeros((d,), jnp.float32),  # beta2
        )

    def apply(self, x, wq, wk, wv, wo, w1, b1, w2, b2, g1, be1, g2, be2):
        """Forward pass: ``(seq, d_model)`` → ``(seq, d_model)``."""
        h = layernorm(x, g1, be1)
        q = matmul(h, wq)
        k = matmul(h, wk)
        v = matmul(h, wv)
        scale = jnp.float32(1.0 / (self.d_model**0.5))
        scores = softmax(matmul(q, k.T) * scale)
        attn = matmul(scores, v)
        y = x + matmul(attn, wo)
        h2 = layernorm(y, g2, be2)
        ff = fused_linear(h2, w1, b1, activation="gelu")
        out = y + fused_linear(ff, w2, b2, activation="none")
        return out

    def input_shapes(self):
        d, f, s = self.d_model, self.d_ff, self.seq
        shapes = [
            (s, d),
            (d, d), (d, d), (d, d), (d, d),
            (d, f), (f,), (f, d), (d,),
            (d,), (d,), (d,), (d,),
        ]
        return [jax.ShapeDtypeStruct(sh, jnp.float32) for sh in shapes]


def ref_mlp(model: MlpClassifier, x, w1, b1, w2, b2, w3, b3):
    """Pure-jnp oracle for :meth:`MlpClassifier.apply`."""
    from .kernels import ref

    h = ref.fused_linear(x, w1, b1, "relu")
    h = ref.fused_linear(h, w2, b2, "gelu")
    return ref.softmax(ref.fused_linear(h, w3, b3, "none"))


def ref_transformer(model: TransformerBlock, x, wq, wk, wv, wo, w1, b1, w2, b2, g1, be1, g2, be2):
    """Pure-jnp oracle for :meth:`TransformerBlock.apply`."""
    from .kernels import ref

    h = ref.layernorm(x, g1, be1)
    q, k, v = ref.matmul(h, wq), ref.matmul(h, wk), ref.matmul(h, wv)
    scale = jnp.float32(1.0 / (model.d_model**0.5))
    attn = ref.matmul(ref.softmax(ref.matmul(q, k.T) * scale), v)
    y = x + ref.matmul(attn, wo)
    h2 = ref.layernorm(y, g2, be2)
    ff = ref.fused_linear(h2, w1, b1, "gelu")
    return y + ref.fused_linear(ff, w2, b2, "none")
