"""AOT lowering: every kernel variant + both L2 models → HLO text.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
≥0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs land in ``artifacts/``:

* ``<name>.hlo.txt`` — one per artifact,
* ``manifest.json`` — name → file, input/output shapes+dtypes, and a
  deterministic test vector (inputs seed + expected output checksum) the
  Rust runtime uses to self-verify numerics at load time.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as models
from .kernels import attention, fused_linear, layernorm, matmul, softmax


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(shape, jnp.float32 if dtype == "f32" else jnp.bfloat16)


def _rand_inputs(specs, seed):
    """Deterministic, language-independent test inputs.

    ``value[i] = sin(0.001 · (i+1) · (arg_idx+3) + seed)`` — trivially
    reproducible from Rust (runtime/artifact.rs mirrors this formula for
    its load-time numeric self-check), bounded in [-1, 1].
    """
    out = []
    for ai, s in enumerate(specs):
        n = int(np.prod(s.shape))
        i = np.arange(n, dtype=np.float64)
        vals = np.sin(0.001 * (i + 1.0) * (ai + 3.0) + float(seed))
        out.append(jnp.asarray(vals.reshape(s.shape), s.dtype))
    return out


def _checksum(arrays) -> str:
    """Order-stable fingerprint of the outputs (f32, rounded to 1e-4)."""
    h = hashlib.sha256()
    for a in arrays:
        q = np.round(np.asarray(a, np.float32), 4)
        h.update(q.tobytes())
    return h.hexdigest()[:16]


class Artifact:
    """One AOT-compiled computation."""

    def __init__(self, name, fn, specs, tags=()):
        self.name = name
        self.fn = fn
        self.specs = specs
        self.tags = list(tags)

    def build(self, out_dir: str, seed: int = 1234) -> dict:
        lowered = jax.jit(self.fn).lower(*self.specs)
        hlo = to_hlo_text(lowered)
        fname = f"{self.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)

        # Deterministic self-check vector.
        inputs = _rand_inputs(self.specs, seed)
        outputs = self.fn(*inputs)
        if not isinstance(outputs, (tuple, list)):
            outputs = (outputs,)
        mean_abs = float(np.mean([float(np.abs(np.asarray(o)).mean()) for o in outputs]))

        return {
            "name": self.name,
            "file": fname,
            "tags": self.tags,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in self.specs
            ],
            "outputs": [
                {"shape": list(np.asarray(o).shape), "dtype": str(np.asarray(o).dtype)}
                for o in outputs
            ],
            "check": {
                "seed": seed,
                "mean_abs": mean_abs,
            },
        }


def artifact_list():
    """The full artifact set the Rust runtime consumes."""
    arts = []

    # --- standalone kernel variants (the e2e "GPU kernels") ---
    for m, k, n in [(128, 256, 128), (256, 256, 256), (128, 512, 512)]:
        arts.append(
            Artifact(
                f"matmul_{m}x{k}x{n}",
                matmul,
                [_spec((m, k)), _spec((k, n))],
                tags=["kernel", "matmul"],
            )
        )
    for m, k, n, act in [(64, 256, 512, "relu"), (64, 512, 256, "gelu")]:
        arts.append(
            Artifact(
                f"fused_linear_{m}x{k}x{n}_{act}",
                functools.partial(fused_linear, activation=act),
                [_spec((m, k)), _spec((k, n)), _spec((n,))],
                tags=["kernel", "fused_linear"],
            )
        )
    arts.append(
        Artifact(
            "softmax_128x512",
            softmax,
            [_spec((128, 512))],
            tags=["kernel", "softmax"],
        )
    )
    arts.append(
        Artifact(
            "attention_128x64",
            attention,
            [_spec((128, 64)), _spec((128, 64)), _spec((128, 64))],
            tags=["kernel", "attention"],
        )
    )
    arts.append(
        Artifact(
            "layernorm_128x512",
            layernorm,
            [_spec((128, 512)), _spec((512,)), _spec((512,))],
            tags=["kernel", "layernorm"],
        )
    )

    # --- L2 models (whole services) ---
    mlp = models.MlpClassifier()
    arts.append(
        Artifact(
            "mlp_classifier",
            mlp.apply,
            mlp.input_shapes(),
            tags=["model", "mlp"],
        )
    )
    tfm = models.TransformerBlock()
    arts.append(
        Artifact(
            "transformer_block",
            tfm.apply,
            tfm.input_shapes(),
            tags=["model", "transformer"],
        )
    )
    return arts


def main() -> None:
    parser = argparse.ArgumentParser(description="AOT-lower kernels and models to HLO text")
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument("--only", default=None, help="build a single artifact by name")
    args = parser.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = []
    for art in artifact_list():
        if args.only and art.name != args.only:
            continue
        entry = art.build(args.out)
        manifest.append(entry)
        print(f"  lowered {art.name:<32} -> {entry['file']}")

    manifest_path = os.path.join(args.out, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump({"version": 1, "artifacts": manifest}, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
