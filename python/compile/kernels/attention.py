"""Fused single-head attention as one Pallas kernel.

The flash-attention insight adapted for TPU (DESIGN.md
§Hardware-Adaptation): instead of materializing the ``(S, S)`` score
matrix in HBM between three separate kernels (two GEMMs + a softmax),
one kernel keeps a ``(bq, S)`` strip of scores resident in VMEM — the
QKᵀ product, the numerically-stable softmax and the V contraction all
happen per query-row-block without an HBM round trip. On a real TPU the
two matmuls hit the MXU and the softmax the VPU, overlapping per block.

VMEM per grid step (f32 words): ``bq·d + S·d·2 + bq·S`` — e.g.
bq=128, S=1024, d=128 → ~1.7 MiB, well inside budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _largest_divisor_leq


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    # (bq, d) query block against the full (S, d) K/V strips.
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(probs, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq",))
def attention(q, k, v, *, bq: int | None = None):
    """``softmax(QKᵀ/√d)·V`` for 2-D ``(S, d)`` inputs, fused in VMEM.

    Args:
      q, k, v: ``(S, d)`` arrays of the same dtype.
      bq: query-row block size (default: largest divisor of S ≤ 128).
    """
    s, d = q.shape
    assert k.shape == (s, d) and v.shape == (s, d), (q.shape, k.shape, v.shape)
    bq = bq or _largest_divisor_leq(s, 128)
    scale = float(1.0 / (d**0.5))

    kernel = functools.partial(_attention_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(s // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        interpret=True,
    )(q, k, v)
