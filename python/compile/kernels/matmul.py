"""Tiled Pallas matmul — the L1 compute hot-spot.

TPU adaptation of the GEMM every serving stack leans on (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging tiles through
shared memory for tensor-core WMMA, the kernel tiles the output into
MXU-shaped ``(bm, bn)`` blocks held in VMEM via ``BlockSpec``; each grid
step keeps an f32 accumulator tile resident while the full-K operand
strips stream HBM→VMEM. Block sizes target the 128×128 MXU systolic
array; accumulation is always f32 (``preferred_element_type``), matching
MXU semantics for bf16 inputs.

VMEM footprint per grid step (f32): ``bm*K + K*bn + bm*bn`` words — e.g.
bm=bn=128, K=2048 → ≈2.2 MiB, comfortably inside the ~16 MiB/core VMEM
budget (documented in DESIGN.md §Perf).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads. Real-TPU performance is assessed analytically (DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ≤ cap (≥1)."""
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile: full-K strip product, f32 accumulate."""
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, y, *, bm: int | None = None, bn: int | None = None):
    """``x @ y`` via a Pallas kernel tiled for VMEM/MXU.

    Args:
      x: ``(M, K)`` array (f32 or bf16).
      y: ``(K, N)`` array (same dtype).
      bm, bn: output tile sizes; default picks the largest divisor ≤128
        (MXU-aligned when shapes allow).
    Returns:
      ``(M, N)`` array in the input dtype (f32 accumulation inside).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm = bm or _largest_divisor_leq(m, 128)
    bn = bn or _largest_divisor_leq(n, 128)
    assert m % bm == 0 and n % bn == 0, "tile sizes must divide the output"

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            # Row strip of x: (bm, K) per grid step i.
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            # Column strip of y: (K, bn) per grid step j.
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def vmem_bytes(m: int, k: int, n: int, bm: int = 128, bn: int = 128,
               bytes_per_el: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (DESIGN.md §Perf)."""
    bm = min(bm, m)
    bn = min(bn, n)
    return (bm * k + k * bn + bm * bn) * bytes_per_el
