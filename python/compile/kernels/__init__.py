"""L1 Pallas kernels (interpret=True) and their pure-jnp oracles."""

from . import ref
from .attention import attention
from .fused_linear import fused_linear
from .layernorm import layernorm
from .matmul import matmul
from .softmax import softmax

__all__ = ["matmul", "fused_linear", "softmax", "layernorm", "attention", "ref"]
