"""Row-tiled, numerically-stable softmax as a Pallas kernel.

Rows are processed in ``(bm, N)`` VMEM-resident strips: max-subtract,
exp, and normalize happen in one pass without spilling intermediates to
HBM (the GPU analogue keeps a row per warp in registers/shared memory;
on TPU the VPU operates on the whole VMEM strip).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _largest_divisor_leq


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm",))
def softmax(x, *, bm: int | None = None):
    """Softmax over the last axis of a 2-D array."""
    m, n = x.shape
    bm = bm or _largest_divisor_leq(m, 256)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)
