"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth the pytest/hypothesis suites compare the
Pallas implementations against (``assert_allclose``). They are also what
the kernels lower to semantically — keep them boring and obviously
correct.
"""

import jax.numpy as jnp


def matmul(x, y):
    """Plain matrix multiply with f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def fused_linear(x, w, b, activation="relu"):
    """Linear layer with fused bias + activation epilogue."""
    out = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    out = out + b.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        # tanh-approximation GELU (matches the Pallas kernel).
        c = jnp.sqrt(2.0 / jnp.pi).astype(jnp.float32)
        out = 0.5 * out * (1.0 + jnp.tanh(c * (out + 0.044715 * out**3)))
    elif activation == "none":
        pass
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return out.astype(x.dtype)


def softmax(x):
    """Numerically-stable row softmax over the last axis."""
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def layernorm(x, gamma, beta, eps=1e-5):
    """Row LayerNorm over the last axis."""
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mean) ** 2, axis=-1, keepdims=True)
    norm = (x32 - mean) / jnp.sqrt(var + eps)
    return (norm * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def attention(q, k, v):
    """Plain single-head attention: softmax(QK^T/sqrt(d)) V."""
    d = q.shape[-1]
    scale = jnp.float32(1.0 / (d**0.5))
    scores = jnp.matmul(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    return jnp.matmul(softmax(scores).astype(jnp.float32),
                      v.astype(jnp.float32)).astype(q.dtype)
