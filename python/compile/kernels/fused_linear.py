"""Fused linear layer (matmul + bias + activation) as one Pallas kernel.

The CUDA idiom this adapts is epilogue fusion: instead of a GEMM kernel
writing to HBM and a second elementwise kernel re-reading it, the bias
add and activation run on the accumulator tile while it is still resident
in VMEM — one HBM round trip saved per output tile, exactly what cutlass
epilogues do with registers/shared memory on GPUs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _largest_divisor_leq


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(jnp.float32)
        acc = 0.5 * acc * (1.0 + jnp.tanh(c * (acc + 0.044715 * acc**3)))
    elif activation == "none":
        pass
    else:
        raise ValueError(f"unknown activation {activation!r}")
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn"))
def fused_linear(x, w, b, *, activation: str = "relu",
                 bm: int | None = None, bn: int | None = None):
    """``act(x @ w + b)`` in one VMEM-resident pass.

    Args:
      x: ``(M, K)`` input activations.
      w: ``(K, N)`` weights.
      b: ``(N,)`` bias.
      activation: ``"relu"`` | ``"gelu"`` | ``"none"``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), f"shape mismatch: {x.shape} {w.shape} {b.shape}"
    bm = bm or _largest_divisor_leq(m, 128)
    bn = bn or _largest_divisor_leq(n, 128)

    grid = (m // bm, n // bn)
    kernel = functools.partial(_fused_linear_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
