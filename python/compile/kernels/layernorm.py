"""Row-tiled LayerNorm as a Pallas kernel.

Same strip pattern as softmax: each grid step holds a ``(bm, N)`` block
in VMEM, computes per-row mean/variance on the VPU, and applies the
affine transform — mean, variance, normalize and scale fused into a
single HBM round trip.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _largest_divisor_leq


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    norm = (x - mean) / jnp.sqrt(var + eps)
    out = norm * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bm"))
def layernorm(x, gamma, beta, *, eps: float = 1e-5, bm: int | None = None):
    """LayerNorm over the last axis of a 2-D array."""
    m, n = x.shape
    assert gamma.shape == (n,) and beta.shape == (n,)
    bm = bm or _largest_divisor_leq(m, 256)
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, gamma, beta)
