"""AOT pipeline integrity: HLO text emission + manifest structure.

These tests lower a subset of artifacts to a temp dir and verify the
emitted HLO parses as text (shape/entry markers present), the manifest is
structurally complete, and re-running is deterministic.
"""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = {}
    for art in aot.artifact_list():
        # Keep the module-scope build fast: skip the big model artifacts
        # (they are exercised by `make artifacts` + the Rust runtime
        # integration tests).
        if "model" in art.tags and art.name != "mlp_classifier":
            continue
        entries[art.name] = art.build(str(out))
    return out, entries


def test_artifact_names_unique():
    names = [a.name for a in aot.artifact_list()]
    assert len(names) == len(set(names))


def test_artifact_list_covers_all_kernels():
    tags = {t for a in aot.artifact_list() for t in a.tags}
    for required in ["matmul", "fused_linear", "softmax", "layernorm", "model"]:
        assert required in tags, f"missing artifact family {required}"


def test_hlo_text_emitted(built):
    out, entries = built
    for name, entry in entries.items():
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        # XLA HLO text structure markers.
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # return_tuple=True: the root is a tuple.
        assert "tuple(" in text or "(f32[" in text, name


def test_manifest_entry_structure(built):
    _, entries = built
    for name, entry in entries.items():
        assert entry["name"] == name
        assert entry["file"].endswith(".hlo.txt")
        assert len(entry["inputs"]) >= 1
        assert len(entry["outputs"]) >= 1
        for spec in entry["inputs"] + entry["outputs"]:
            assert all(d > 0 for d in spec["shape"]), name
            assert spec["dtype"] in ("float32", "bfloat16"), name
        assert entry["check"]["mean_abs"] > 0.0


def test_check_vector_deterministic(built):
    out, entries = built
    art = next(a for a in aot.artifact_list() if a.name == "softmax_128x512")
    again = art.build(str(out))
    assert again["check"] == entries["softmax_128x512"]["check"]


def test_matmul_hlo_contains_dot(built):
    out, entries = built
    entry = entries["matmul_128x256x128"]
    text = open(os.path.join(out, entry["file"])).read()
    assert "dot(" in text, "matmul artifact must lower to an HLO dot"


def test_manifest_written_by_main(tmp_path, monkeypatch):
    import sys

    out = tmp_path / "arts"
    monkeypatch.setattr(
        sys, "argv", ["aot", "--out", str(out), "--only", "softmax_128x512"]
    )
    aot.main()
    manifest = json.load(open(out / "manifest.json"))
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) == 1
    assert manifest["artifacts"][0]["name"] == "softmax_128x512"
