"""L2 correctness: models composed of Pallas kernels vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import (
    MlpClassifier,
    TransformerBlock,
    ref_mlp,
    ref_transformer,
)

seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestMlpClassifier:
    @settings(max_examples=8, deadline=None)
    @given(seed=seeds, batch=st.sampled_from([1, 8, 32]))
    def test_matches_oracle(self, seed, batch):
        model = MlpClassifier(batch=batch, d_in=64, d_hidden=96, n_classes=16)
        params = model.init(seed)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, 64), jnp.float32)
        got = model.apply(x, *params)
        want = ref_mlp(model, x, *params)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_outputs_are_probabilities(self):
        model = MlpClassifier(batch=8, d_in=64, d_hidden=96, n_classes=16)
        params = model.init(3)
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)
        probs = np.asarray(model.apply(x, *params))
        assert (probs >= 0).all()
        np.testing.assert_allclose(probs.sum(axis=-1), np.ones(8), rtol=1e-5)

    def test_input_shapes_match_apply(self):
        model = MlpClassifier()
        specs = model.input_shapes()
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        out = model.apply(*args)
        assert out.shape == (model.batch, model.n_classes)


class TestTransformerBlock:
    @settings(max_examples=6, deadline=None)
    @given(seed=seeds)
    def test_matches_oracle(self, seed):
        model = TransformerBlock(seq=32, d_model=64, d_ff=96)
        params = model.init(seed)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (32, 64), jnp.float32)
        got = model.apply(x, *params)
        want = ref_transformer(model, x, *params)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_residual_path(self):
        # With zero weights everywhere, the block must be the identity.
        model = TransformerBlock(seq=16, d_model=32, d_ff=48)
        params = model.init(0)
        zeroed = tuple(jnp.zeros_like(p) for p in params)
        x = jax.random.normal(jax.random.PRNGKey(9), (16, 32), jnp.float32)
        out = model.apply(x, *zeroed)
        np.testing.assert_allclose(out, x, rtol=1e-5, atol=1e-6)

    def test_shape_preserved(self):
        model = TransformerBlock()
        specs = model.input_shapes()
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        out = model.apply(*args)
        assert out.shape == (model.seq, model.d_model)
