"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/seeds; every case asserts allclose
against ``kernels.ref``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, fused_linear, layernorm, matmul, ref, softmax

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(size=shape), dtype)


dims = st.sampled_from([1, 2, 3, 4, 8, 16, 32, 96, 128, 160, 256])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestMatmul:
    @settings(**SETTINGS)
    @given(m=dims, k=dims, n=dims, seed=seeds)
    def test_matches_ref_f32(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = _rand(rng, (m, k)), _rand(rng, (k, n))
        np.testing.assert_allclose(matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(m=st.sampled_from([8, 32, 128]), seed=seeds)
    def test_matches_ref_bf16(self, m, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (m, 64), jnp.bfloat16)
        y = _rand(rng, (64, m), jnp.bfloat16)
        got = np.asarray(matmul(x, y), np.float32)
        want = np.asarray(ref.matmul(x, y), np.float32)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)

    def test_explicit_tiles(self):
        rng = np.random.default_rng(0)
        x, y = _rand(rng, (256, 128)), _rand(rng, (128, 256))
        out = matmul(x, y, bm=64, bn=128)
        np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-5, atol=1e-5)

    def test_rejects_bad_contraction(self):
        rng = np.random.default_rng(0)
        with pytest.raises(AssertionError):
            matmul(_rand(rng, (4, 5)), _rand(rng, (6, 4)))

    def test_identity(self):
        eye = jnp.eye(32, dtype=jnp.float32)
        rng = np.random.default_rng(1)
        x = _rand(rng, (32, 32))
        np.testing.assert_allclose(matmul(x, eye), x, rtol=1e-6, atol=1e-6)


class TestFusedLinear:
    @settings(**SETTINGS)
    @given(
        m=dims,
        k=dims,
        n=dims,
        act=st.sampled_from(["relu", "gelu", "none"]),
        seed=seeds,
    )
    def test_matches_ref(self, m, k, n, act, seed):
        rng = np.random.default_rng(seed)
        x, w, b = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (n,))
        got = fused_linear(x, w, b, activation=act)
        want = ref.fused_linear(x, w, b, act)
        # rtol 1e-4: f32 contraction-order differences between the Pallas
        # interpret-mode dot and jnp.matmul grow with K.
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_relu_clamps_negative(self):
        x = jnp.ones((4, 4), jnp.float32)
        w = -jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        out = fused_linear(x, w, b, activation="relu")
        assert float(jnp.min(out)) == 0.0

    def test_unknown_activation_rejected(self):
        x = jnp.ones((4, 4), jnp.float32)
        w = jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        with pytest.raises(ValueError):
            fused_linear(x, w, b, activation="swish")


class TestSoftmax:
    @settings(**SETTINGS)
    @given(m=dims, n=dims, seed=seeds)
    def test_matches_ref(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (m, n))
        np.testing.assert_allclose(softmax(x), ref.softmax(x), rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(m=dims, n=dims, seed=seeds)
    def test_rows_sum_to_one(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (m, n)) * 10.0
        sums = jnp.sum(softmax(x), axis=-1)
        np.testing.assert_allclose(sums, np.ones(m), rtol=1e-5, atol=1e-5)

    def test_large_values_stable(self):
        # Max-subtraction keeps huge logits finite.
        x = jnp.asarray([[1e4, 1e4 + 1.0, -1e4]], jnp.float32)
        out = np.asarray(softmax(x))
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-5)


class TestLayernorm:
    @settings(**SETTINGS)
    @given(m=dims, n=st.sampled_from([2, 4, 16, 96, 256]), seed=seeds)
    def test_matches_ref(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (m, n))
        gamma, beta = _rand(rng, (n,)), _rand(rng, (n,))
        np.testing.assert_allclose(
            layernorm(x, gamma, beta), ref.layernorm(x, gamma, beta), rtol=1e-4, atol=1e-5
        )

    @settings(max_examples=10, deadline=None)
    @given(m=dims, seed=seeds)
    def test_unit_affine_gives_standardized_rows(self, m, seed):
        rng = np.random.default_rng(seed)
        n = 128
        x = _rand(rng, (m, n)) * 7.0 + 3.0
        out = np.asarray(layernorm(x, jnp.ones((n,)), jnp.zeros((n,))))
        np.testing.assert_allclose(out.mean(axis=-1), np.zeros(m), atol=1e-4)
        np.testing.assert_allclose(out.std(axis=-1), np.ones(m), rtol=1e-2)


class TestAttention:
    @settings(max_examples=15, deadline=None)
    @given(
        s=st.sampled_from([1, 2, 4, 16, 64, 128]),
        d=st.sampled_from([4, 16, 32, 64]),
        seed=seeds,
    )
    def test_matches_ref(self, s, d, seed):
        rng = np.random.default_rng(seed)
        q, k, v = (_rand(rng, (s, d)) for _ in range(3))
        np.testing.assert_allclose(
            attention(q, k, v), ref.attention(q, k, v), rtol=1e-4, atol=1e-5
        )

    def test_uniform_scores_average_v(self):
        # Identical queries/keys -> uniform attention -> output is the
        # mean of V rows.
        s, d = 8, 16
        q = jnp.ones((s, d), jnp.float32)
        k = jnp.ones((s, d), jnp.float32)
        rng = np.random.default_rng(0)
        v = _rand(rng, (s, d))
        out = np.asarray(attention(q, k, v))
        expect = np.tile(np.asarray(v).mean(axis=0), (s, 1))
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)

    def test_explicit_block_size(self):
        rng = np.random.default_rng(1)
        q, k, v = (_rand(rng, (64, 32)) for _ in range(3))
        out = attention(q, k, v, bq=16)
        np.testing.assert_allclose(out, ref.attention(q, k, v), rtol=1e-4, atol=1e-5)
