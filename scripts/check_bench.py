#!/usr/bin/env python3
"""Validate the perf-trajectory artifacts: BENCH_sched.json (scheduler
hot path), BENCH_sim.json (simulator event core) and
PARETO_preempt.json (the preemption Pareto sweep).

Checks, per bench artifact:

1. shape — version, suite id, non-empty case list, required numeric
   fields per case (name, iters, mean_ns, median_ns, p95_ns, min_ns);
2. the headline gates are present:
   * BENCH_sched.json — case ``best_prio_fit/select_n512`` declaring
     ``budget_ns`` ≤ 1000 (a BestPrioFit decision at 512 queued requests
     must stay ≤ 1 µs mean — DESIGN.md §Perf), and case
     ``preempt/decide`` declaring ``budget_ns`` ≤ 2000 (the full
     preempt cycle — plan, cut, tombstone, remnant re-queue, re-select
     — stays priced; ADR-007);
   * BENCH_sim.json — case ``sim/events_per_sec`` declaring
     ``budget_events_per_sec`` ≥ 500000 and meeting it (a full
     deterministic run must sustain ≥ 500 k events/s through the
     calendar-wheel event core — ADR-003);
3. budgets — every case that declares ``budget_ns`` has
   ``mean_ns`` ≤ ``budget_ns``; every case that declares
   ``budget_events_per_sec`` has ``events_per_sec`` ≥ the floor.

PARETO_preempt.json (``fikit preempt --json``) is validated for shape
and the paper band: ``experiment == "preemption"``, ``passed`` true, a
``band`` of [0.86, 1.0], non-empty ``points`` each carrying
``workload``/``policy``/``high_speedup``/``low_ratio`` (every hybrid
point inside the band), and non-empty ``checks`` all passing.

Exit 0 on success, 1 on any failure. A missing artifact is a SKIP
(exit 0 for that artifact) because the offline container has no Rust
toolchain to produce it; the regeneration commands are printed so CI
(or any box with cargo) can produce and gate all three:

    cargo run --manifest-path rust/Cargo.toml --release -- bench --json
    cargo run --manifest-path rust/Cargo.toml --release -- preempt --json
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

REQUIRED_CASE_FIELDS = ("name", "iters", "mean_ns", "median_ns", "p95_ns", "min_ns")
EXPECTED_VERSION = 1  # keep in lockstep with rust/src/benchsuite.rs

SCHED_HEADLINE = "best_prio_fit/select_n512"
SCHED_HEADLINE_BUDGET_NS = 1000
SCHED_PREEMPT_CASE = "preempt/decide"
SCHED_PREEMPT_BUDGET_NS = 2000
SIM_HEADLINE = "sim/events_per_sec"
SIM_HEADLINE_FLOOR = 500_000

PARETO_BAND = (0.86, 1.0)

REGEN = "  cargo run --manifest-path rust/Cargo.toml --release -- bench --json"
REGEN_PARETO = "  cargo run --manifest-path rust/Cargo.toml --release -- preempt --json"


def fail(artifact: str, msg: str) -> int:
    print(f"check_bench: FAIL: {artifact}: {msg}")
    return 1


def check_artifact(path: Path, suite: str) -> int:
    """Shared shape + budget validation. Returns 0/1; SKIP counts as 0."""
    if not path.exists():
        print(
            f"check_bench: SKIP: {path.name} not found (no cargo in this "
            f"container). Regenerate with:\n{REGEN}"
        )
        return 0

    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return fail(path.name, f"unreadable JSON: {e}")

    if doc.get("version") != EXPECTED_VERSION:
        return fail(path.name, f"version {doc.get('version')!r} != {EXPECTED_VERSION}")
    if doc.get("suite") != suite:
        return fail(path.name, f"unexpected suite {doc.get('suite')!r} (want {suite!r})")
    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        return fail(path.name, "cases must be a non-empty list")

    names = set()
    for i, case in enumerate(cases):
        if not isinstance(case, dict):
            return fail(path.name, f"case {i} is not an object")
        for field in REQUIRED_CASE_FIELDS:
            if field not in case:
                return fail(path.name, f"case {i} missing field {field!r}")
        for field in REQUIRED_CASE_FIELDS[1:]:
            v = case[field]
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                return fail(
                    path.name, f"case {case['name']!r}: {field} must be a non-negative int"
                )
        if case["name"] in names:
            return fail(path.name, f"duplicate case name {case['name']!r}")
        names.add(case["name"])
        for gate in ("budget_ns", "budget_events_per_sec", "events_per_sec"):
            v = case.get(gate)
            if v is not None and (not isinstance(v, int) or isinstance(v, bool) or v <= 0):
                return fail(path.name, f"case {case['name']!r}: bad {gate} {v!r}")

    by_name = {c["name"]: c for c in cases}

    if suite == "scheduler_hotpath":
        headline = by_name.get(SCHED_HEADLINE)
        if headline is None:
            return fail(path.name, f"required case {SCHED_HEADLINE!r} missing")
        if (
            headline.get("budget_ns") is None
            or headline["budget_ns"] > SCHED_HEADLINE_BUDGET_NS
        ):
            return fail(
                path.name,
                f"{SCHED_HEADLINE!r} must declare budget_ns <= "
                f"{SCHED_HEADLINE_BUDGET_NS} (got {headline.get('budget_ns')!r})",
            )
        preempt = by_name.get(SCHED_PREEMPT_CASE)
        if preempt is None:
            return fail(path.name, f"required case {SCHED_PREEMPT_CASE!r} missing")
        if (
            preempt.get("budget_ns") is None
            or preempt["budget_ns"] > SCHED_PREEMPT_BUDGET_NS
        ):
            return fail(
                path.name,
                f"{SCHED_PREEMPT_CASE!r} must declare budget_ns <= "
                f"{SCHED_PREEMPT_BUDGET_NS} (got {preempt.get('budget_ns')!r})",
            )
        headline_desc = (
            f"{SCHED_HEADLINE} mean {headline['mean_ns']}ns "
            f"(budget {headline['budget_ns']}ns), "
            f"{SCHED_PREEMPT_CASE} mean {preempt['mean_ns']}ns "
            f"(budget {preempt['budget_ns']}ns)"
        )
    else:
        headline = by_name.get(SIM_HEADLINE)
        if headline is None:
            return fail(path.name, f"required case {SIM_HEADLINE!r} missing")
        floor = headline.get("budget_events_per_sec")
        if floor is None or floor < SIM_HEADLINE_FLOOR:
            return fail(
                path.name,
                f"{SIM_HEADLINE!r} must declare budget_events_per_sec >= "
                f"{SIM_HEADLINE_FLOOR} (got {floor!r})",
            )
        if headline.get("events_per_sec") is None:
            return fail(path.name, f"{SIM_HEADLINE!r} missing events_per_sec")
        headline_desc = (
            f"{SIM_HEADLINE} {headline['events_per_sec']} events/s "
            f"(floor {floor})"
        )

    violations = [
        f"  {c['name']}: mean {c['mean_ns']}ns > budget {c['budget_ns']}ns"
        for c in cases
        if c.get("budget_ns") is not None and c["mean_ns"] > c["budget_ns"]
    ]
    violations += [
        f"  {c['name']}: {c['events_per_sec']} events/s < floor "
        f"{c['budget_events_per_sec']} events/s"
        for c in cases
        if c.get("budget_events_per_sec") is not None
        and c.get("events_per_sec", 0) < c["budget_events_per_sec"]
    ]
    if violations:
        print(f"check_bench: FAIL: {path.name}: budget violations:")
        print("\n".join(violations))
        return 1

    gated = sum(
        1
        for c in cases
        if c.get("budget_ns") is not None or c.get("budget_events_per_sec") is not None
    )
    print(
        f"check_bench: OK: {path.name}: {len(cases)} cases, {gated} budget-gated, "
        f"{headline_desc}"
    )
    return 0


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_pareto(path: Path) -> int:
    """Validate the preemption Pareto artifact. SKIP when absent."""
    if not path.exists():
        print(
            f"check_bench: SKIP: {path.name} not found (no cargo in this "
            f"container). Regenerate with:\n{REGEN_PARETO}"
        )
        return 0

    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return fail(path.name, f"unreadable JSON: {e}")

    if doc.get("experiment") != "preemption":
        return fail(
            path.name, f"experiment {doc.get('experiment')!r} != 'preemption'"
        )
    if doc.get("passed") is not True:
        return fail(path.name, f"passed must be true (got {doc.get('passed')!r})")
    band = doc.get("band")
    if not isinstance(band, dict) or not _num(band.get("low")) or not _num(band.get("high")):
        return fail(path.name, f"band must be {{low, high}} numbers (got {band!r})")
    if (band["low"], band["high"]) != PARETO_BAND:
        return fail(
            path.name,
            f"band [{band['low']}, {band['high']}] != the paper band "
            f"[{PARETO_BAND[0]}, {PARETO_BAND[1]}]",
        )

    points = doc.get("points")
    if not isinstance(points, list) or not points:
        return fail(path.name, "points must be a non-empty list")
    hybrids = 0
    for i, pt in enumerate(points):
        if not isinstance(pt, dict):
            return fail(path.name, f"point {i} is not an object")
        for field in ("workload", "policy"):
            if not isinstance(pt.get(field), str) or not pt[field]:
                return fail(path.name, f"point {i}: missing/empty {field!r}")
        for field in ("high_speedup", "low_ratio"):
            if not _num(pt.get(field)) or pt[field] <= 0:
                return fail(
                    path.name,
                    f"point {i} ({pt.get('workload')}/{pt.get('policy')}): "
                    f"{field} must be a positive number (got {pt.get(field)!r})",
                )
        if pt["policy"] == "hybrid":
            hybrids += 1
            if pt["low_ratio"] < band["low"]:
                return fail(
                    path.name,
                    f"hybrid point {pt['workload']!r}: low_ratio "
                    f"{pt['low_ratio']} below the band floor {band['low']}",
                )
    if hybrids == 0:
        return fail(path.name, "no hybrid points — the acceptance arm is missing")

    checks = doc.get("checks")
    if not isinstance(checks, list) or not checks:
        return fail(path.name, "checks must be a non-empty list")
    for i, chk in enumerate(checks):
        if not isinstance(chk, dict) or not isinstance(chk.get("name"), str):
            return fail(path.name, f"check {i} must be an object with a name")
        if chk.get("passed") is not True:
            return fail(
                path.name,
                f"check {chk['name']!r} not passed: {chk.get('detail')!r}",
            )

    print(
        f"check_bench: OK: {path.name}: {len(points)} Pareto points "
        f"({hybrids} hybrid, all inside [{band['low']}, {band['high']}]), "
        f"{len(checks)} checks passed"
    )
    return 0


def main() -> int:
    rc = 0
    rc |= check_artifact(REPO / "BENCH_sched.json", "scheduler_hotpath")
    rc |= check_artifact(REPO / "BENCH_sim.json", "sim_core")
    rc |= check_pareto(REPO / "PARETO_preempt.json")
    return rc


if __name__ == "__main__":
    sys.exit(main())
