#!/usr/bin/env python3
"""Validate the perf-trajectory artifacts: BENCH_sched.json (scheduler
hot path) and BENCH_sim.json (simulator event core).

Checks, per artifact:

1. shape — version, suite id, non-empty case list, required numeric
   fields per case (name, iters, mean_ns, median_ns, p95_ns, min_ns);
2. the headline gate is present:
   * BENCH_sched.json — case ``best_prio_fit/select_n512`` declaring
     ``budget_ns`` ≤ 1000 (a BestPrioFit decision at 512 queued requests
     must stay ≤ 1 µs mean — DESIGN.md §Perf);
   * BENCH_sim.json — case ``sim/events_per_sec`` declaring
     ``budget_events_per_sec`` ≥ 500000 and meeting it (a full
     deterministic run must sustain ≥ 500 k events/s through the
     calendar-wheel event core — ADR-003);
3. budgets — every case that declares ``budget_ns`` has
   ``mean_ns`` ≤ ``budget_ns``; every case that declares
   ``budget_events_per_sec`` has ``events_per_sec`` ≥ the floor.

Exit 0 on success, 1 on any failure. A missing artifact is a SKIP
(exit 0 for that artifact) because the offline container has no Rust
toolchain to produce it; the single regeneration command is printed so
CI (or any box with cargo) can produce and gate both:

    cargo run --manifest-path rust/Cargo.toml --release -- bench --json
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

REQUIRED_CASE_FIELDS = ("name", "iters", "mean_ns", "median_ns", "p95_ns", "min_ns")
EXPECTED_VERSION = 1  # keep in lockstep with rust/src/benchsuite.rs

SCHED_HEADLINE = "best_prio_fit/select_n512"
SCHED_HEADLINE_BUDGET_NS = 1000
SIM_HEADLINE = "sim/events_per_sec"
SIM_HEADLINE_FLOOR = 500_000

REGEN = "  cargo run --manifest-path rust/Cargo.toml --release -- bench --json"


def fail(artifact: str, msg: str) -> int:
    print(f"check_bench: FAIL: {artifact}: {msg}")
    return 1


def check_artifact(path: Path, suite: str) -> int:
    """Shared shape + budget validation. Returns 0/1; SKIP counts as 0."""
    if not path.exists():
        print(
            f"check_bench: SKIP: {path.name} not found (no cargo in this "
            f"container). Regenerate with:\n{REGEN}"
        )
        return 0

    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return fail(path.name, f"unreadable JSON: {e}")

    if doc.get("version") != EXPECTED_VERSION:
        return fail(path.name, f"version {doc.get('version')!r} != {EXPECTED_VERSION}")
    if doc.get("suite") != suite:
        return fail(path.name, f"unexpected suite {doc.get('suite')!r} (want {suite!r})")
    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        return fail(path.name, "cases must be a non-empty list")

    names = set()
    for i, case in enumerate(cases):
        if not isinstance(case, dict):
            return fail(path.name, f"case {i} is not an object")
        for field in REQUIRED_CASE_FIELDS:
            if field not in case:
                return fail(path.name, f"case {i} missing field {field!r}")
        for field in REQUIRED_CASE_FIELDS[1:]:
            v = case[field]
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                return fail(
                    path.name, f"case {case['name']!r}: {field} must be a non-negative int"
                )
        if case["name"] in names:
            return fail(path.name, f"duplicate case name {case['name']!r}")
        names.add(case["name"])
        for gate in ("budget_ns", "budget_events_per_sec", "events_per_sec"):
            v = case.get(gate)
            if v is not None and (not isinstance(v, int) or isinstance(v, bool) or v <= 0):
                return fail(path.name, f"case {case['name']!r}: bad {gate} {v!r}")

    by_name = {c["name"]: c for c in cases}

    if suite == "scheduler_hotpath":
        headline = by_name.get(SCHED_HEADLINE)
        if headline is None:
            return fail(path.name, f"required case {SCHED_HEADLINE!r} missing")
        if (
            headline.get("budget_ns") is None
            or headline["budget_ns"] > SCHED_HEADLINE_BUDGET_NS
        ):
            return fail(
                path.name,
                f"{SCHED_HEADLINE!r} must declare budget_ns <= "
                f"{SCHED_HEADLINE_BUDGET_NS} (got {headline.get('budget_ns')!r})",
            )
        headline_desc = (
            f"{SCHED_HEADLINE} mean {headline['mean_ns']}ns "
            f"(budget {headline['budget_ns']}ns)"
        )
    else:
        headline = by_name.get(SIM_HEADLINE)
        if headline is None:
            return fail(path.name, f"required case {SIM_HEADLINE!r} missing")
        floor = headline.get("budget_events_per_sec")
        if floor is None or floor < SIM_HEADLINE_FLOOR:
            return fail(
                path.name,
                f"{SIM_HEADLINE!r} must declare budget_events_per_sec >= "
                f"{SIM_HEADLINE_FLOOR} (got {floor!r})",
            )
        if headline.get("events_per_sec") is None:
            return fail(path.name, f"{SIM_HEADLINE!r} missing events_per_sec")
        headline_desc = (
            f"{SIM_HEADLINE} {headline['events_per_sec']} events/s "
            f"(floor {floor})"
        )

    violations = [
        f"  {c['name']}: mean {c['mean_ns']}ns > budget {c['budget_ns']}ns"
        for c in cases
        if c.get("budget_ns") is not None and c["mean_ns"] > c["budget_ns"]
    ]
    violations += [
        f"  {c['name']}: {c['events_per_sec']} events/s < floor "
        f"{c['budget_events_per_sec']} events/s"
        for c in cases
        if c.get("budget_events_per_sec") is not None
        and c.get("events_per_sec", 0) < c["budget_events_per_sec"]
    ]
    if violations:
        print(f"check_bench: FAIL: {path.name}: budget violations:")
        print("\n".join(violations))
        return 1

    gated = sum(
        1
        for c in cases
        if c.get("budget_ns") is not None or c.get("budget_events_per_sec") is not None
    )
    print(
        f"check_bench: OK: {path.name}: {len(cases)} cases, {gated} budget-gated, "
        f"{headline_desc}"
    )
    return 0


def main() -> int:
    rc = 0
    rc |= check_artifact(REPO / "BENCH_sched.json", "scheduler_hotpath")
    rc |= check_artifact(REPO / "BENCH_sim.json", "sim_core")
    return rc


if __name__ == "__main__":
    sys.exit(main())
