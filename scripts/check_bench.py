#!/usr/bin/env python3
"""Validate BENCH_sched.json (the scheduler hot-path perf trajectory).

Checks, in order:

1. shape — version, suite id, non-empty case list, required numeric
   fields per case (name, iters, mean_ns, median_ns, p95_ns, min_ns);
2. the headline gate is present: case ``best_prio_fit/select_n512``
   declaring ``budget_ns`` ≤ 1000 (a BestPrioFit decision at 512 queued
   requests must stay ≤ 1 µs mean — DESIGN.md §Perf);
3. budgets — every case that declares ``budget_ns`` has
   ``mean_ns`` ≤ ``budget_ns``.

Exit 0 on success, 1 on any failure. A missing artifact is a SKIP
(exit 0) because the offline container has no Rust toolchain to produce
it; the single regeneration command is printed so CI (or any box with
cargo) can produce and gate it:

    cargo run --manifest-path rust/Cargo.toml --release -- bench --json
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH = REPO / "BENCH_sched.json"

REQUIRED_CASE_FIELDS = ("name", "iters", "mean_ns", "median_ns", "p95_ns", "min_ns")
HEADLINE_CASE = "best_prio_fit/select_n512"
HEADLINE_BUDGET_NS = 1000
EXPECTED_VERSION = 1  # keep in lockstep with rust/src/benchsuite.rs


def fail(msg: str) -> "int":
    print(f"check_bench: FAIL: {msg}")
    return 1


def main() -> int:
    if not BENCH.exists():
        print(
            "check_bench: SKIP: BENCH_sched.json not found (no cargo in this "
            "container). Regenerate with:\n"
            "  cargo run --manifest-path rust/Cargo.toml --release -- bench --json"
        )
        return 0

    try:
        doc = json.loads(BENCH.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return fail(f"unreadable JSON: {e}")

    if doc.get("version") != EXPECTED_VERSION:
        return fail(f"version {doc.get('version')!r} != {EXPECTED_VERSION}")
    if doc.get("suite") != "scheduler_hotpath":
        return fail(f"unexpected suite {doc.get('suite')!r}")
    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        return fail("cases must be a non-empty list")

    names = set()
    for i, case in enumerate(cases):
        if not isinstance(case, dict):
            return fail(f"case {i} is not an object")
        for field in REQUIRED_CASE_FIELDS:
            if field not in case:
                return fail(f"case {i} missing field {field!r}")
        for field in REQUIRED_CASE_FIELDS[1:]:
            v = case[field]
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                return fail(f"case {case['name']!r}: {field} must be a non-negative int")
        if case["name"] in names:
            return fail(f"duplicate case name {case['name']!r}")
        names.add(case["name"])
        budget = case.get("budget_ns")
        if budget is not None and (not isinstance(budget, int) or budget <= 0):
            return fail(f"case {case['name']!r}: bad budget_ns {budget!r}")

    by_name = {c["name"]: c for c in cases}
    headline = by_name.get(HEADLINE_CASE)
    if headline is None:
        return fail(f"required case {HEADLINE_CASE!r} missing")
    if headline.get("budget_ns") is None or headline["budget_ns"] > HEADLINE_BUDGET_NS:
        return fail(
            f"{HEADLINE_CASE!r} must declare budget_ns <= {HEADLINE_BUDGET_NS} "
            f"(got {headline.get('budget_ns')!r})"
        )

    violations = [
        f"  {c['name']}: mean {c['mean_ns']}ns > budget {c['budget_ns']}ns"
        for c in cases
        if c.get("budget_ns") is not None and c["mean_ns"] > c["budget_ns"]
    ]
    if violations:
        print("check_bench: FAIL: hot-path budget violations:")
        print("\n".join(violations))
        return 1

    gated = sum(1 for c in cases if c.get("budget_ns") is not None)
    print(
        f"check_bench: OK: {len(cases)} cases, {gated} budget-gated, "
        f"{HEADLINE_CASE} mean {headline['mean_ns']}ns "
        f"(budget {headline['budget_ns']}ns)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
