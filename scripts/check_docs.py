#!/usr/bin/env python3
"""Docs health check — the repo's "docs job".

Three checks, zero dependencies:

1. **Markdown links**: every relative link target in every tracked
   `*.md` file must exist (anchors are checked against the target
   file's headings).
2. **DESIGN.md section references**: every ``DESIGN.md §<token>``
   citation in source and docs (``*.rs``, ``*.py``, ``*.md``) must
   resolve to a real ``§<token>`` heading in ``rust/DESIGN.md`` — the
   dangling-citation failure mode this script exists to prevent.
3. **rustdoc**: ``cargo doc --no-deps`` must build with zero warnings
   (skipped with a notice when no cargo toolchain is available, e.g. in
   the offline container).

Exit code 0 = healthy. Run from anywhere inside the repo:

    python3 scripts/check_docs.py
"""

from __future__ import annotations

import os
import re
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DESIGN = os.path.join(REPO, "rust", "DESIGN.md")
SKIP_DIRS = {".git", ".claude", "target", "node_modules", "__pycache__", ".venv"}

# [text](target) — excluding images and in-cell pipes; good enough for
# the hand-written markdown in this repo.
MD_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SECTION_REF = re.compile(r"DESIGN\.md\s+§([A-Za-z0-9_-]+)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def walk(exts: tuple[str, ...]):
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if d not in SKIP_DIRS]
        for name in files:
            if name.endswith(exts):
                yield os.path.join(root, name)


def github_anchor(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    text = heading.strip().lower()
    text = re.sub(r"[^\w\s§-]", "", text, flags=re.UNICODE)
    text = text.replace("§", "")
    return re.sub(r"\s+", "-", text.strip())


def anchors_of(md_path: str) -> set[str]:
    with open(md_path, encoding="utf-8") as f:
        content = f.read()
    return {github_anchor(h) for h in HEADING.findall(content)}


def check_markdown_links() -> list[str]:
    errors = []
    for path in walk((".md",)):
        with open(path, encoding="utf-8") as f:
            content = f.read()
        for target in MD_LINK.findall(content):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, anchor = target.partition("#")
            if base:
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), base)
                )
                if not os.path.exists(resolved):
                    errors.append(
                        f"{os.path.relpath(path, REPO)}: broken link -> {target}"
                    )
                    continue
            else:
                resolved = path
            if anchor and resolved.endswith(".md"):
                if github_anchor("# " + anchor) not in anchors_of(resolved) and \
                        anchor not in anchors_of(resolved):
                    errors.append(
                        f"{os.path.relpath(path, REPO)}: broken anchor -> {target}"
                    )
    return errors


def check_design_refs() -> list[str]:
    if not os.path.exists(DESIGN):
        return ["rust/DESIGN.md does not exist but the code cites it"]
    with open(DESIGN, encoding="utf-8") as f:
        design = f.read()
    sections = set(re.findall(r"^#{1,6}\s+§([A-Za-z0-9_-]+)", design, re.MULTILINE))
    errors = []
    for path in walk((".rs", ".py", ".md")):
        if os.path.abspath(path) == os.path.abspath(DESIGN):
            continue
        # ISSUE.md is the per-PR task brief: it talks *about* "§N"
        # references generically rather than citing a section.
        if os.path.basename(path) == "ISSUE.md":
            continue
        with open(path, encoding="utf-8") as f:
            content = f.read()
        for tok in SECTION_REF.findall(content):
            if tok not in sections:
                errors.append(
                    f"{os.path.relpath(path, REPO)}: cites DESIGN.md §{tok}, "
                    f"but rust/DESIGN.md has no such section "
                    f"(has: {', '.join(sorted(sections))})"
                )
    return errors


def check_rustdoc() -> list[str]:
    if shutil.which("cargo") is None:
        print("  [skip] cargo not on PATH — rustdoc check skipped")
        return []
    env = dict(os.environ, RUSTDOCFLAGS="-D warnings")
    proc = subprocess.run(
        ["cargo", "doc", "--no-deps", "--quiet"],
        cwd=os.path.join(REPO, "rust"),
        env=env,
        capture_output=True,
        text=True,
    )
    if proc.returncode != 0:
        tail = "\n".join((proc.stderr or proc.stdout).splitlines()[-30:])
        return [f"cargo doc --no-deps failed:\n{tail}"]
    return []


def main() -> int:
    failures = 0
    for name, check in [
        ("markdown links", check_markdown_links),
        ("DESIGN.md § references", check_design_refs),
        ("rustdoc (cargo doc --no-deps)", check_rustdoc),
    ]:
        print(f"checking {name} ...")
        errors = check()
        for e in errors:
            print(f"  FAIL {e}")
        failures += len(errors)
        if not errors:
            print("  ok")
    if failures:
        print(f"\n{failures} docs problem(s) found")
        return 1
    print("\ndocs healthy")
    return 0


if __name__ == "__main__":
    sys.exit(main())
