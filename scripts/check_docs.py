#!/usr/bin/env python3
"""Docs health check — the repo's "docs job".

Eight checks, zero dependencies:

1. **Markdown links**: every relative link target in every tracked
   `*.md` file must exist (anchors are checked against the target
   file's headings).
2. **DESIGN.md section references**: every ``DESIGN.md §<token>``
   citation in source and docs (``*.rs``, ``*.py``, ``*.md``) must
   resolve to a real ``§<token>`` heading in ``rust/DESIGN.md`` — the
   dangling-citation failure mode this script exists to prevent.
   (This is also what keeps the §9 online-refinement citations
   honest.)
3. **DESIGN.md table of contents**: every ``§<token>`` heading must be
   listed in the TOC bullet list and vice versa — a new section that
   is not announced, or a TOC entry whose section was renamed away,
   fails the check.
4. **ADR cross-links**: every ``ADR-<NNN>`` mention anywhere in the
   docs/source must resolve to an existing
   ``rust/docs/ADR-<NNN>-*.md`` file, and each ADR's ``Depends on`` /
   ``Unlocks`` sections may only reference ADRs that exist.
5. **Wire-protocol coverage**: every variant of ``ClientMsg`` /
   ``SchedulerMsg`` / ``PeerMsg`` in ``rust/src/hook/protocol.rs`` must
   be documented (backticked) in DESIGN.md's "Wire protocol" section —
   a message added to the wire without prose fails here. Probed: the
   variant list is parsed from the Rust source, not hand-maintained.
6. **Concurrency-backend coverage**: every variant of
   ``ConcurrencyBackend`` in ``rust/src/simulator/backend.rs`` must be
   documented (backticked) in DESIGN.md's "Concurrency backends"
   section — a hardware model added to the simulator seam without
   prose fails here. Probed from the Rust source like check 5.
7. **Preemption-policy coverage**: every variant of
   ``PreemptionPolicy`` in ``rust/src/coordinator/fikit.rs`` must be
   documented (backticked) in DESIGN.md's "Kernel-level preemption"
   section — a policy added to the preemption tier without prose
   fails here. Probed from the Rust source like checks 5 and 6.
8. **rustdoc**: ``cargo doc --no-deps`` must build with zero warnings
   (skipped with a notice when no cargo toolchain is available, e.g. in
   the offline container).

Exit code 0 = healthy. Run from anywhere inside the repo:

    python3 scripts/check_docs.py
"""

from __future__ import annotations

import os
import re
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DESIGN = os.path.join(REPO, "rust", "DESIGN.md")
SKIP_DIRS = {".git", ".claude", "target", "node_modules", "__pycache__", ".venv"}

# [text](target) — excluding images and in-cell pipes; good enough for
# the hand-written markdown in this repo.
MD_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SECTION_REF = re.compile(r"DESIGN\.md\s+§([A-Za-z0-9_-]+)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def walk(exts: tuple[str, ...]):
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if d not in SKIP_DIRS]
        for name in files:
            if name.endswith(exts):
                yield os.path.join(root, name)


def github_anchor(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    text = heading.strip().lower()
    text = re.sub(r"[^\w\s§-]", "", text, flags=re.UNICODE)
    text = text.replace("§", "")
    return re.sub(r"\s+", "-", text.strip())


def anchors_of(md_path: str) -> set[str]:
    with open(md_path, encoding="utf-8") as f:
        content = f.read()
    return {github_anchor(h) for h in HEADING.findall(content)}


def check_markdown_links() -> list[str]:
    errors = []
    for path in walk((".md",)):
        with open(path, encoding="utf-8") as f:
            content = f.read()
        for target in MD_LINK.findall(content):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, anchor = target.partition("#")
            if base:
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), base)
                )
                if not os.path.exists(resolved):
                    errors.append(
                        f"{os.path.relpath(path, REPO)}: broken link -> {target}"
                    )
                    continue
            else:
                resolved = path
            if anchor and resolved.endswith(".md"):
                if github_anchor("# " + anchor) not in anchors_of(resolved) and \
                        anchor not in anchors_of(resolved):
                    errors.append(
                        f"{os.path.relpath(path, REPO)}: broken anchor -> {target}"
                    )
    return errors


def check_design_refs() -> list[str]:
    if not os.path.exists(DESIGN):
        return ["rust/DESIGN.md does not exist but the code cites it"]
    with open(DESIGN, encoding="utf-8") as f:
        design = f.read()
    sections = set(re.findall(r"^#{1,6}\s+§([A-Za-z0-9_-]+)", design, re.MULTILINE))
    errors = []
    for path in walk((".rs", ".py", ".md")):
        if os.path.abspath(path) == os.path.abspath(DESIGN):
            continue
        # ISSUE.md is the per-PR task brief: it talks *about* "§N"
        # references generically rather than citing a section.
        if os.path.basename(path) == "ISSUE.md":
            continue
        with open(path, encoding="utf-8") as f:
            content = f.read()
        for tok in SECTION_REF.findall(content):
            if tok not in sections:
                errors.append(
                    f"{os.path.relpath(path, REPO)}: cites DESIGN.md §{tok}, "
                    f"but rust/DESIGN.md has no such section "
                    f"(has: {', '.join(sorted(sections))})"
                )
    return errors


def check_design_toc() -> list[str]:
    """The DESIGN.md TOC and the actual §-headings must agree."""
    if not os.path.exists(DESIGN):
        return []  # check_design_refs already reports this
    with open(DESIGN, encoding="utf-8") as f:
        design = f.read()
    headings = set(re.findall(r"^#{2,6}\s+§([A-Za-z0-9_-]+)", design, re.MULTILINE))
    toc = set(re.findall(r"^\*\s+\[§([A-Za-z0-9_-]+)[\s\]]", design, re.MULTILINE))
    errors = []
    for tok in sorted(headings - toc):
        errors.append(f"rust/DESIGN.md: §{tok} heading missing from the TOC")
    for tok in sorted(toc - headings):
        errors.append(f"rust/DESIGN.md: TOC lists §{tok} but no such heading exists")
    return errors


ADR_REF = re.compile(r"\bADR-(\d{3})\b")


def check_adr_links() -> list[str]:
    """Every ADR-NNN mention must resolve to rust/docs/ADR-NNN-*.md."""
    adr_dir = os.path.join(REPO, "rust", "docs")
    existing: set[str] = set()
    if os.path.isdir(adr_dir):
        for name in os.listdir(adr_dir):
            m = re.match(r"ADR-(\d{3})-.*\.md$", name)
            if m:
                existing.add(m.group(1))
    errors = []
    for path in walk((".rs", ".py", ".md")):
        # ISSUE.md is the per-PR brief; SNIPPETS.md quotes exemplar code
        # from other repositories (whose ADR numbering is their own).
        if os.path.basename(path) in ("ISSUE.md", "SNIPPETS.md"):
            continue
        with open(path, encoding="utf-8") as f:
            content = f.read()
        for num in set(ADR_REF.findall(content)):
            if num not in existing:
                errors.append(
                    f"{os.path.relpath(path, REPO)}: references ADR-{num}, "
                    f"but rust/docs/ has no ADR-{num}-*.md "
                    f"(existing: {', '.join('ADR-' + n for n in sorted(existing)) or 'none'})"
                )
    # Each ADR's "Depends on" / "Unlocks" sections must cite real ADRs
    # (covered by the scan above) and, when they cite one, link it.
    for num in sorted(existing):
        for name in os.listdir(adr_dir):
            if not name.startswith(f"ADR-{num}-"):
                continue
            with open(os.path.join(adr_dir, name), encoding="utf-8") as f:
                content = f.read()
            for ref in set(ADR_REF.findall(content)) - {num}:
                if f"ADR-{ref}-" not in content:
                    errors.append(
                        f"rust/docs/{name}: mentions ADR-{ref} without linking "
                        f"its file (expected a [ADR-{ref}](ADR-{ref}-*.md) link)"
                    )
    # The lowest-numbered ADR doubles as the decision index: every other
    # ADR must be mentioned (and therefore, by the rule above, linked)
    # from it, so a new ADR nobody wires into the index fails here.
    if existing:
        index_num = min(existing)
        index_name = next(
            name
            for name in sorted(os.listdir(adr_dir))
            if name.startswith(f"ADR-{index_num}-")
        )
        with open(os.path.join(adr_dir, index_name), encoding="utf-8") as f:
            index_content = f.read()
        index_refs = set(ADR_REF.findall(index_content))
        for num in sorted(existing - {index_num} - index_refs):
            errors.append(
                f"rust/docs/{index_name}: the decision index never mentions "
                f"ADR-{num} — add a link so new ADRs are discoverable from "
                f"the first one"
            )
    return errors


PROTOCOL_RS = os.path.join(REPO, "rust", "src", "hook", "protocol.rs")
PROTOCOL_ENUMS = ("ClientMsg", "SchedulerMsg", "PeerMsg")


def protocol_variants() -> dict[str, list[str]]:
    """Parse the wire-message enum variant names out of protocol.rs."""
    with open(PROTOCOL_RS, encoding="utf-8") as f:
        lines = f.readlines()
    variants: dict[str, list[str]] = {}
    enum = None
    depth = 0
    variant = re.compile(r"^\s{4}([A-Z]\w*)\s*(?:\{|\(|,|$)")
    for line in lines:
        if enum is None:
            m = re.match(r"\s*pub enum (\w+)\s*\{", line)
            if m and m.group(1) in PROTOCOL_ENUMS:
                enum = m.group(1)
                variants[enum] = []
                depth = line.count("{") - line.count("}")
            continue
        if depth == 1:
            m = variant.match(line)
            if m:
                variants[enum].append(m.group(1))
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            enum = None
    return variants


def check_protocol_docs() -> list[str]:
    """Every wire-message variant must be documented in DESIGN.md."""
    if not os.path.exists(PROTOCOL_RS):
        return ["rust/src/hook/protocol.rs does not exist"]
    if not os.path.exists(DESIGN):
        return []  # check_design_refs already reports this
    variants = protocol_variants()
    errors = []
    for enum in PROTOCOL_ENUMS:
        if not variants.get(enum):
            errors.append(
                f"rust/src/hook/protocol.rs: found no variants for enum "
                f"{enum} — parser or protocol drifted"
            )
    with open(DESIGN, encoding="utf-8") as f:
        design = f.read()
    m = re.search(r"^#{2,6}\s+.*Wire protocol.*$", design, re.MULTILINE)
    if not m:
        return errors + [
            'rust/DESIGN.md: no "Wire protocol" heading — the protocol '
            "vocabulary has nowhere to be documented"
        ]
    level = len(design[m.start():].split(None, 1)[0])
    rest = design[m.end():]
    nxt = re.search(rf"^#{{2,{level}}}\s", rest, re.MULTILINE)
    section = rest[: nxt.start()] if nxt else rest
    for enum, names in variants.items():
        for name in names:
            if not re.search(rf"`[^`]*\b{name}\b[^`]*`", section):
                errors.append(
                    f"rust/DESIGN.md: wire-protocol section never documents "
                    f"`{name}` ({enum} variant in rust/src/hook/protocol.rs)"
                )
    return errors


BACKEND_RS = os.path.join(REPO, "rust", "src", "simulator", "backend.rs")


def backend_variants() -> list[str]:
    """Parse the ConcurrencyBackend variant names out of backend.rs."""
    with open(BACKEND_RS, encoding="utf-8") as f:
        lines = f.readlines()
    variants: list[str] = []
    inside = False
    depth = 0
    variant = re.compile(r"^\s{4}([A-Z]\w*)\s*(?:\{|\(|,|$)")
    for line in lines:
        if not inside:
            if re.match(r"\s*pub enum ConcurrencyBackend\s*\{", line):
                inside = True
                depth = line.count("{") - line.count("}")
            continue
        if depth == 1:
            m = variant.match(line)
            if m:
                variants.append(m.group(1))
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            break
    return variants


def check_backend_docs() -> list[str]:
    """Every ConcurrencyBackend variant must be documented (backticked)
    in DESIGN.md's "Concurrency backends" section — a hardware model
    added to the simulator seam without prose fails here."""
    if not os.path.exists(BACKEND_RS):
        return ["rust/src/simulator/backend.rs does not exist"]
    if not os.path.exists(DESIGN):
        return []  # check_design_refs already reports this
    variants = backend_variants()
    if not variants:
        return [
            "rust/src/simulator/backend.rs: found no ConcurrencyBackend "
            "variants — parser or enum drifted"
        ]
    with open(DESIGN, encoding="utf-8") as f:
        design = f.read()
    m = re.search(r"^#{2,6}\s+.*Concurrency backends.*$", design, re.MULTILINE)
    if not m:
        return [
            'rust/DESIGN.md: no "Concurrency backends" heading — the '
            "hardware-concurrency vocabulary has nowhere to be documented"
        ]
    level = len(design[m.start():].split(None, 1)[0])
    rest = design[m.end():]
    nxt = re.search(rf"^#{{2,{level}}}\s", rest, re.MULTILINE)
    section = rest[: nxt.start()] if nxt else rest
    errors = []
    for name in variants:
        if not re.search(rf"`[^`]*\b{name}\b[^`]*`", section):
            errors.append(
                f"rust/DESIGN.md: concurrency-backends section never "
                f"documents `{name}` (ConcurrencyBackend variant in "
                f"rust/src/simulator/backend.rs)"
            )
    return errors


FIKIT_RS = os.path.join(REPO, "rust", "src", "coordinator", "fikit.rs")


def preemption_variants() -> list[str]:
    """Parse the PreemptionPolicy variant names out of fikit.rs."""
    with open(FIKIT_RS, encoding="utf-8") as f:
        lines = f.readlines()
    variants: list[str] = []
    inside = False
    depth = 0
    variant = re.compile(r"^\s{4}([A-Z]\w*)\s*(?:\{|\(|,|$)")
    for line in lines:
        if not inside:
            if re.match(r"\s*pub enum PreemptionPolicy\s*\{", line):
                inside = True
                depth = line.count("{") - line.count("}")
            continue
        if depth == 1:
            m = variant.match(line)
            if m:
                variants.append(m.group(1))
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            break
    return variants


def check_preemption_docs() -> list[str]:
    """Every PreemptionPolicy variant must be documented (backticked)
    in DESIGN.md's "Kernel-level preemption" section — a policy added
    to the preemption tier without prose fails here."""
    if not os.path.exists(FIKIT_RS):
        return ["rust/src/coordinator/fikit.rs does not exist"]
    if not os.path.exists(DESIGN):
        return []  # check_design_refs already reports this
    variants = preemption_variants()
    if not variants:
        return [
            "rust/src/coordinator/fikit.rs: found no PreemptionPolicy "
            "variants — parser or enum drifted"
        ]
    with open(DESIGN, encoding="utf-8") as f:
        design = f.read()
    m = re.search(r"^#{2,6}\s+.*Kernel-level preemption.*$", design, re.MULTILINE)
    if not m:
        return [
            'rust/DESIGN.md: no "Kernel-level preemption" heading — the '
            "preemption vocabulary has nowhere to be documented"
        ]
    level = len(design[m.start():].split(None, 1)[0])
    rest = design[m.end():]
    nxt = re.search(rf"^#{{2,{level}}}\s", rest, re.MULTILINE)
    section = rest[: nxt.start()] if nxt else rest
    errors = []
    for name in variants:
        if not re.search(rf"`[^`]*\b{name}\b[^`]*`", section):
            errors.append(
                f"rust/DESIGN.md: kernel-level-preemption section never "
                f"documents `{name}` (PreemptionPolicy variant in "
                f"rust/src/coordinator/fikit.rs)"
            )
    return errors


def check_rustdoc() -> list[str]:
    if shutil.which("cargo") is None:
        print("  [skip] cargo not on PATH — rustdoc check skipped")
        return []
    env = dict(os.environ, RUSTDOCFLAGS="-D warnings")
    proc = subprocess.run(
        ["cargo", "doc", "--no-deps", "--quiet"],
        cwd=os.path.join(REPO, "rust"),
        env=env,
        capture_output=True,
        text=True,
    )
    if proc.returncode != 0:
        tail = "\n".join((proc.stderr or proc.stdout).splitlines()[-30:])
        return [f"cargo doc --no-deps failed:\n{tail}"]
    return []


def main() -> int:
    failures = 0
    for name, check in [
        ("markdown links", check_markdown_links),
        ("DESIGN.md § references", check_design_refs),
        ("DESIGN.md table of contents", check_design_toc),
        ("ADR cross-links", check_adr_links),
        ("wire-protocol coverage in DESIGN.md", check_protocol_docs),
        ("concurrency-backend coverage in DESIGN.md", check_backend_docs),
        ("preemption-policy coverage in DESIGN.md", check_preemption_docs),
        ("rustdoc (cargo doc --no-deps)", check_rustdoc),
    ]:
        print(f"checking {name} ...")
        errors = check()
        for e in errors:
            print(f"  FAIL {e}")
        failures += len(errors)
        if not errors:
            print("  ok")
    if failures:
        print(f"\n{failures} docs problem(s) found")
        return 1
    print("\ndocs healthy")
    return 0


if __name__ == "__main__":
    sys.exit(main())
